//! `msrep` — command-line launcher for the MSREP multi-GPU SpMV framework.
//!
//! ```text
//! msrep info                               platform presets + artifact status
//! msrep gen       --out m.mtx ...          generate a synthetic matrix
//! msrep profile   --matrix m.mtx           structural profile (Table-2 style)
//! msrep partition --matrix m.mtx --np 8    partition + load/imbalance report
//! msrep run       --matrix m.mtx ...       one mSpMV with full breakdown
//! msrep suite                              Table-2 analog summary
//! msrep serve-bench ...                    batched multi-tenant serving sim
//! msrep solver-bench ...                   plan-reusing iterative solvers
//! msrep spgemm-bench ...                   flop-balanced multi-GPU SpGEMM
//! msrep sptrsv-bench ...                   level-scheduled triangular solves
//! msrep cluster-bench --nodes 1,2,4 ...    two-tier scale-out node sweep
//! msrep trace --scenario small ...         traced tour of every subsystem
//! msrep calibrate --quick ...              fit sim constants to measured walls
//! msrep perf --against BENCH_history.jsonl continuous perf suite + noise gate
//! ```
//!
//! The paper-figure regeneration lives in `cargo bench` /
//! `cargo run --example paper_figures`.

use std::process::ExitCode;

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, io, stats, FormatKind, Matrix};
use msrep::report::{format_duration_s, format_pct, Table};
use msrep::sim::Platform;
use msrep::util::cli::{Args, Parser};
use msrep::workload;
use msrep::{Error, Result};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let Some(cmd) = argv.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let rest = argv[1..].to_vec();
    match cmd.as_str() {
        "info" => cmd_info(),
        "gen" => cmd_gen(rest),
        "profile" => cmd_profile(rest),
        "partition" => cmd_partition(rest),
        "run" => cmd_run(rest),
        "suite" => cmd_suite(),
        "serve-bench" => cmd_serve_bench(rest),
        "solver-bench" => cmd_solver_bench(rest),
        "spgemm-bench" => cmd_spgemm_bench(rest),
        "sptrsv-bench" => cmd_sptrsv_bench(rest),
        "autoplan-bench" => cmd_autoplan_bench(rest),
        "cluster-bench" => cmd_cluster_bench(rest),
        "trace" => cmd_trace(rest),
        "calibrate" => cmd_calibrate(rest),
        "perf" => cmd_perf(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(Error::Usage(format!(
            "unknown command '{other}' (expected info | gen | profile | partition | run | \
             suite | serve-bench | solver-bench | spgemm-bench | sptrsv-bench | \
             autoplan-bench | cluster-bench | trace | calibrate | perf; try `msrep help`)"
        ))),
    }
}

fn print_usage() {
    println!(
        "msrep — MSREP multi-GPU sparse matrix framework (paper reproduction)\n\n\
         commands:\n\
         \x20 info        platform presets and artifact status\n\
         \x20 gen         generate a synthetic matrix (--help for flags)\n\
         \x20 profile     structural profile of a MatrixMarket file\n\
         \x20 partition   partition a matrix and report per-GPU loads\n\
         \x20 run         run one multi-GPU SpMV with a full breakdown\n\
         \x20 suite       list the Table-2 evaluation suite analogs\n\
         \x20 serve-bench simulate batched multi-tenant SpMV serving (--help for flags)\n\
         \x20 solver-bench run the plan-reusing iterative solvers (CG, Jacobi, PageRank) \
         with the amortization report (--help for flags)\n\
         \x20 spgemm-bench run the SpGEMM scenario chains (A², Galerkin R·A·P, Markov) \
         comparing nnz- vs flop-balanced planning (--help for flags)\n\
         \x20 sptrsv-bench run the level-scheduled triangular-solve scenarios \
         comparing the level-balanced wavefront split against naive row blocks \
         (--help for flags)\n\
         \x20 autoplan-bench run the profile-driven format tuner over the \
         format-selection scenarios and check it against every fixed format \
         (--help for flags)\n\
         \x20 cluster-bench sweep the two-tier cluster engine over node counts, \
         comparing MSREP's partial-merge allgather against the broadcast \
         baseline and the topology-aware against the topology-blind level-0 \
         split, with memoized CommPlan cache counters (--help for flags)\n\
         \x20 trace       run a traced tour of every subsystem (SpMV, SpGEMM, \
         SpTRSV, CG, serving) and export the span timeline as Chrome \
         trace-event JSON + an ASCII Gantt (--help for flags)\n\
         \x20 calibrate   replay the workload suites on the measured backend \
         and least-squares fit the sim constants against the recorded walls, \
         emitting BENCH_calibration.json (--help for flags)\n\
         \x20 perf        replay the pinned perf suite N times, append a \
         median+MAD record to BENCH_history.jsonl, and optionally gate \
         against a baseline with span-level regression attribution \
         (--help for flags)\n"
    );
}

fn cmd_info() -> Result<()> {
    println!("platforms:");
    for p in [Platform::summit(), Platform::dgx1()] {
        println!(
            "  {:<8} {} GPUs, {} NUMA domains, {:?} host link, {:.0} GB/s HBM",
            p.name,
            p.num_gpus,
            p.num_numa,
            p.host_link,
            p.hbm_bw / 1e9
        );
    }
    let dir = msrep::runtime::default_artifact_dir();
    match msrep::runtime::Manifest::load(&dir) {
        Ok(m) => println!(
            "artifacts: {} entries at {}{}",
            m.len(),
            dir.display(),
            if m.quick { " (QUICK build)" } else { "" }
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn gen_parser() -> Parser {
    Parser::new()
        .flag("out", "output MatrixMarket path", Some("matrix.mtx"))
        .flag("kind", "power-law | uniform | banded | two-band", Some("power-law"))
        .flag("m", "rows", Some("10000"))
        .flag("n", "cols (default: m)", None)
        .flag("nnz", "non-zeros", Some("100000"))
        .flag("r", "power-law exponent R", Some("2.0"))
        .flag("ratio", "two-band low:high nnz ratio", Some("8.0"))
        .flag("band", "banded matrix bandwidth", Some("5"))
        .flag("seed", "PRNG seed", Some("42"))
}

fn cmd_gen(argv: Vec<String>) -> Result<()> {
    let p = gen_parser();
    if argv.iter().any(|a| a == "--help") {
        println!("msrep gen — generate a synthetic matrix\n{}", p.help());
        return Ok(());
    }
    let a = p.parse(argv)?;
    let m = a.usize_or("m", 10_000)?;
    let n = a.usize_or("n", m)?;
    let nnz = a.usize_or("nnz", 100_000)?;
    let seed = a.u64_or("seed", 42)?;
    let kind = a.str_or("kind", "power-law");
    let coo = match kind.as_str() {
        "power-law" => gen::power_law(m, n, nnz, a.f64_or("r", 2.0)?, seed),
        "uniform" => gen::uniform(m, n, nnz, seed),
        "banded" => gen::banded(m, n, a.usize_or("band", 5)?, seed),
        "two-band" => gen::two_band(m, n, nnz, a.f64_or("ratio", 8.0)?, seed),
        other => return Err(Error::Usage(format!("unknown kind '{other}'"))),
    };
    let out = a.str_or("out", "matrix.mtx");
    io::write_matrix_market_file(&out, &coo)?;
    println!("wrote {} ({}x{}, {} nnz) to {out}", kind, coo.rows(), coo.cols(), coo.nnz());
    Ok(())
}

fn load_matrix(a: &Args) -> Result<Matrix> {
    if let Some(name) = a.get("suite") {
        let e = workload::by_name(name)
            .ok_or_else(|| Error::Usage(format!("unknown suite matrix '{name}'")))?;
        return Ok(Matrix::Coo(workload::suite_matrix(&e)));
    }
    let path = a
        .get("matrix")
        .ok_or_else(|| Error::Usage("--matrix <file.mtx> or --suite <name> required".into()))?;
    Ok(Matrix::Coo(io::read_matrix_market_file(path)?))
}

fn to_format(mat: Matrix, format: FormatKind) -> Matrix {
    convert::to_format(&mat, format)
}

/// Re-price a platform through a saved sim-constants profile when
/// `--constants <file>` is set (the JSON `msrep calibrate --save` emits —
/// a whole calibration report is accepted too; see
/// [`msrep::sim::SimConstants::from_json`]).
fn apply_constants(platform: Platform, a: &Args) -> Result<Platform> {
    match a.get("constants") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            Ok(platform.with_consts(msrep::sim::SimConstants::from_json(&text)?))
        }
        None => Ok(platform),
    }
}

fn cmd_profile(argv: Vec<String>) -> Result<()> {
    let p = Parser::new()
        .flag("matrix", "MatrixMarket file", None)
        .flag("suite", "suite matrix name", None)
        .bool_flag("no-spgemm", "skip the per-row SpGEMM flop histogram");
    let a = p.parse(argv)?;
    let mat = load_matrix(&a)?;
    let coo = convert::to_coo(&mat);
    let prof = stats::profile(&coo);
    let mut t = Table::new(["property", "value"]);
    t.row(["rows", &prof.m.to_string()]);
    t.row(["cols", &prof.n.to_string()]);
    t.row(["nnz", &prof.nnz.to_string()]);
    t.row(["density", &format!("{:.3e}", prof.density)]);
    t.row(["mean nnz/row", &format!("{:.2}", prof.mean_row_nnz)]);
    t.row(["max nnz/row", &prof.max_row_nnz.to_string()]);
    t.row(["max nnz/col", &prof.max_col_nnz.to_string()]);
    t.row([
        "power-law R".to_string(),
        prof.r_exponent.map_or("n/a".to_string(), |r| format!("{r:.2}")),
    ]);
    print!("{}", t.render());
    if !a.is_set("no-spgemm") {
        println!();
        if mat.rows() == mat.cols() {
            // SpGEMM work preview for C = A·A: where nnz-balanced planning
            // would land before any plan is built
            let csr = convert::to_csr(&mat);
            let brn = msrep::spgemm::b_row_nnz(&mat);
            let rf = msrep::spgemm::row_flops(&csr, &brn);
            print!("{}", msrep::report::render_flop_skew(&rf));
        } else {
            println!(
                "(per-row SpGEMM flop histogram skipped: A·A needs a square matrix, \
                 got {}x{})",
                mat.rows(),
                mat.cols()
            );
        }
    }
    Ok(())
}

fn cmd_partition(argv: Vec<String>) -> Result<()> {
    let p = Parser::new()
        .flag("matrix", "MatrixMarket file", None)
        .flag("suite", "suite matrix name", None)
        .flag("np", "partitions", Some("8"))
        .flag("format", "csr | csc | coo | psell", Some("csr"))
        .flag("strategy", "balanced | blocks", Some("balanced"));
    let a = p.parse(argv)?;
    let format = FormatKind::parse(&a.str_or("format", "csr"))
        .ok_or_else(|| Error::Usage("bad --format".into()))?;
    let mat = to_format(load_matrix(&a)?, format);
    let np = a.usize_or("np", 8)?;
    let strategy = a.str_or("strategy", "balanced");
    let out = match strategy.as_str() {
        "balanced" => msrep::coordinator::partitioner::balanced(&mat, np)?,
        "blocks" => msrep::coordinator::partitioner::baseline(&mat, np)?,
        other => return Err(Error::Usage(format!("unknown strategy '{other}'"))),
    };
    let mut t = Table::new(["gpu", "nnz", "share"]);
    let total: u64 = out.loads().iter().sum();
    for (g, &l) in out.loads().iter().enumerate() {
        t.row([
            g.to_string(),
            l.to_string(),
            format_pct(l as f64 / total.max(1) as f64),
        ]);
    }
    print!("{}", t.render());
    println!("imbalance (max/mean): {:.4}", out.imbalance());
    Ok(())
}

fn run_parser() -> Parser {
    Parser::new()
        .flag("matrix", "MatrixMarket file", None)
        .flag("suite", "suite matrix name (e.g. HV15R)", None)
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag("format", "csr | csc | coo | psell", Some("csr"))
        .flag("backend", "pjrt | cpu | measured", Some("pjrt"))
        .flag("alpha", "alpha scalar", Some("1.0"))
        .flag("beta", "beta scalar", Some("0.0"))
        .flag("iters", "SpMV iterations", Some("1"))
        .bool_flag("no-numa", "disable NUMA-aware placement")
        .bool_flag("verify", "check against the CPU oracle")
        .bool_flag("timeline", "render the modeled phase timeline + per-GPU loads")
        .flag("trace", "export the span timeline as Chrome trace-event JSON", None)
        .flag("constants", "sim-constants profile JSON (from `msrep calibrate --save`)", None)
}

fn cmd_run(argv: Vec<String>) -> Result<()> {
    let p = run_parser();
    if argv.iter().any(|a| a == "--help") {
        println!("msrep run — one multi-GPU SpMV\n{}", p.help());
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = apply_constants(Platform::by_name(&a.str_or("platform", "dgx1"))?, &a)?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let format = FormatKind::parse(&a.str_or("format", "csr"))
        .ok_or_else(|| Error::Usage("bad --format".into()))?;
    let backend = Backend::parse(&a.str_or("backend", "pjrt"))
        .ok_or_else(|| Error::Usage("bad --backend (expected pjrt | cpu | measured)".into()))?;
    let mat = to_format(load_matrix(&a)?, format);
    let alpha = a.f64_or("alpha", 1.0)? as f32;
    let beta = a.f64_or("beta", 0.0)? as f32;
    let iters = a.usize_or("iters", 1)?;

    let mut engine = Engine::new(RunConfig {
        platform,
        num_gpus,
        mode,
        format,
        backend,
        numa_aware: if a.is_set("no-numa") { Some(false) } else { None },
        strategy_override: None,
    })?;
    let recorder = msrep::obs::TraceRecorder::enabled();
    if a.get("trace").is_some() {
        engine.set_recorder(recorder.clone());
    }

    let x = gen::dense_vector(mat.cols(), 7);
    let y0 = gen::dense_vector(mat.rows(), 8);
    let mut last = None;
    for _ in 0..iters.max(1) {
        last = Some(engine.spmv(&mat, &x, alpha, beta, Some(&y0))?);
    }
    let rep = last.unwrap();
    let mm = &rep.metrics;

    println!(
        "matrix: {}x{} nnz={} format={} | {} mode={} gpus={}",
        mat.rows(),
        mat.cols(),
        mat.nnz(),
        format.name(),
        engine.config().platform.name,
        mode.label(),
        num_gpus
    );
    let mut t = Table::new(["phase", "modeled", "share"]);
    t.row([
        "partition".to_string(),
        format_duration_s(mm.t_partition),
        format_pct(mm.partition_overhead()),
    ]);
    t.row([
        "h2d".to_string(),
        format_duration_s(mm.t_h2d),
        format_pct(mm.t_h2d / mm.modeled_total),
    ]);
    t.row([
        "compute".to_string(),
        format_duration_s(mm.t_compute),
        format_pct(mm.t_compute / mm.modeled_total),
    ]);
    t.row([
        "merge".to_string(),
        format_duration_s(mm.t_merge),
        format_pct(mm.merge_overhead()),
    ]);
    t.row(["TOTAL".to_string(), format_duration_s(mm.modeled_total), "100.0%".to_string()]);
    print!("{}", t.render());
    println!(
        "imbalance {:.3} | modeled {:.2} GFLOP/s | measured host: partition {} exec {} merge {}",
        mm.imbalance,
        mm.gflops(),
        format_duration_s(mm.measured_partition),
        format_duration_s(mm.measured_exec),
        format_duration_s(mm.measured_merge),
    );
    if !mm.measured_busy.is_empty() {
        let busy: Vec<String> = mm
            .measured_busy
            .iter()
            .enumerate()
            .map(|(g, &b)| format!("gpu{g} {}", format_duration_s(b)))
            .collect();
        println!("measured per-GPU kernel walls: {}", busy.join(" | "));
    }

    if a.is_set("timeline") {
        println!();
        print!("{}", msrep::report::render_timeline(mm, 50));
        println!();
        print!("{}", msrep::report::render_loads(mm, 50));
    }

    if a.is_set("verify") {
        let mut expect = y0.clone();
        msrep::spmv::spmv_matrix(&mat, &x, alpha, beta, &mut expect)?;
        let max_rel = rep
            .y
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f32, f32::max);
        println!("verify: max relative error vs CPU oracle = {max_rel:.2e}");
        if max_rel > 1e-2 {
            return Err(Error::InvalidMatrix(format!("verification FAILED ({max_rel})")));
        }
    }
    if let Some(path) = a.get("trace") {
        export_trace(&recorder, path)?;
    }
    Ok(())
}

fn serve_parser() -> Parser {
    Parser::new()
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs per engine", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag("tenants", "distinct matrices (multi-tenant traffic)", Some("3"))
        .flag("requests", "total requests in the trace", Some("240"))
        .flag("rate", "mean arrival rate (requests per modeled second)", Some("200000"))
        .flag("m", "rows = cols of each tenant matrix", Some("4096"))
        .flag("nnz", "non-zeros of each tenant matrix", Some("200000"))
        .flag("batch", "max batch size K", Some("8"))
        .flag("flush-us", "batch flush deadline (modeled µs)", Some("100"))
        .flag("engines", "engine pool size", Some("1"))
        .flag("queue", "per-matrix queue capacity (backpressure)", Some("128"))
        .flag("deadline-us", "per-request deadline (modeled µs, 0 = none)", Some("0"))
        .flag("cache", "plan-cache capacity (0 disables)", Some("16"))
        .flag("seed", "trace PRNG seed", Some("42"))
        .bool_flag("compare", "also run the sequential no-cache baseline")
        .flag("trace", "export the span timeline as Chrome trace-event JSON", None)
}

/// Build the synthetic multi-tenant trace: exponential inter-arrivals at
/// `rate`, tenants drawn uniformly, fresh dense x per request.
fn serve_trace(
    tenants: &[msrep::serve::MatrixId],
    n: usize,
    requests: usize,
    rate: f64,
    deadline_s: Option<f64>,
    seed: u64,
) -> Vec<msrep::serve::SpmvRequest> {
    let mut rng = msrep::util::rng::Rng::new(seed);
    let mut t = 0.0f64;
    (0..requests)
        .map(|i| {
            t += -(1.0 - rng.f64()).ln() / rate;
            msrep::serve::SpmvRequest {
                matrix: tenants[rng.usize_below(tenants.len())],
                x: gen::dense_vector(n, seed.wrapping_add(1000 + i as u64)),
                alpha: 1.0,
                arrival_s: t,
                deadline_s,
            }
        })
        .collect()
}

fn cmd_serve_bench(argv: Vec<String>) -> Result<()> {
    let p = serve_parser();
    if argv.iter().any(|a| a == "--help") {
        println!("msrep serve-bench — batched multi-tenant SpMV serving simulation\n{}", p.help());
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = Platform::by_name(&a.str_or("platform", "dgx1"))?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let tenants = a.usize_or("tenants", 3)?.max(1);
    let requests = a.usize_or("requests", 240)?;
    let rate = a.f64_or("rate", 200_000.0)?;
    let m = a.usize_or("m", 4_096)?;
    let nnz = a.usize_or("nnz", 200_000)?;
    let seed = a.u64_or("seed", 42)?;
    if rate <= 0.0 {
        return Err(Error::Usage("--rate must be > 0".into()));
    }
    let deadline_us = a.f64_or("deadline-us", 0.0)?;
    let deadline_s = if deadline_us > 0.0 { Some(deadline_us * 1e-6) } else { None };
    let cfg = msrep::serve::ServeConfig {
        run: RunConfig {
            platform,
            num_gpus,
            mode,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: None,
        },
        num_engines: a.usize_or("engines", 1)?,
        max_batch: a.usize_or("batch", 8)?,
        flush_deadline_s: a.f64_or("flush-us", 100.0)? * 1e-6,
        queue_capacity: a.usize_or("queue", 128)?,
        plan_cache_capacity: a.usize_or("cache", 16)?,
        cluster: None,
    };

    println!(
        "serve-bench: {} tenants x ({m} x {m}, ~{nnz} nnz power-law), {requests} requests \
         at ~{rate:.0} req/s (modeled)",
        tenants
    );
    println!(
        "server: {} x {} GPUs, mode {}, batch {}, flush {:.0} µs, {} engine(s), cache {}\n",
        cfg.run.platform.name,
        cfg.run.num_gpus,
        cfg.run.mode.label(),
        cfg.max_batch,
        cfg.flush_deadline_s * 1e6,
        cfg.num_engines,
        cfg.plan_cache_capacity,
    );

    let build = |c: msrep::serve::ServeConfig| -> Result<(msrep::serve::Server, Vec<msrep::serve::SpmvRequest>)> {
        let mut server = msrep::serve::Server::new(c)?;
        let ids: Vec<msrep::serve::MatrixId> = (0..tenants)
            .map(|t| {
                let coo = gen::power_law(m, m, nnz, 2.0, seed.wrapping_add(t as u64));
                server.register(Matrix::Csr(convert::to_csr(&Matrix::Coo(coo))))
            })
            .collect();
        let trace = serve_trace(&ids, m, requests, rate, deadline_s, seed);
        Ok((server, trace))
    };

    let (mut server, trace) = build(cfg.clone())?;
    let recorder = msrep::obs::TraceRecorder::enabled();
    if a.get("trace").is_some() {
        server.set_recorder(&recorder);
    }
    let report = server.run(trace)?;
    print!("{}", report.render());
    if let Some(path) = a.get("trace") {
        export_trace(&recorder, path)?;
    }

    if a.is_set("compare") {
        let (mut base_server, base_trace) = build(cfg.sequential_baseline())?;
        let base = base_server.run(base_trace)?;
        println!("\nsequential per-request baseline (batch 1, no plan cache):");
        print!("{}", base.render());
        let speedup = if base.throughput_rps() > 0.0 {
            report.throughput_rps() / base.throughput_rps()
        } else {
            0.0
        };
        println!("\nbatched throughput speedup over sequential: {speedup:.2}x");
    }
    Ok(())
}

fn solver_parser() -> Parser {
    Parser::new()
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag("format", "csr | csc | coo | psell (CG/Jacobi input format)", Some("csr"))
        .flag("backend", "cpu | measured (identical numerics, measured adds walls)", Some("cpu"))
        .flag(
            "method",
            "cg | pcg (ILU(0) on the Poisson stencil) | jacobi | power | pagerank | all",
            Some("all"),
        )
        .flag("source", "reused (plan once) | cold (re-partition per iteration)", Some("reused"))
        .flag("m", "rows = cols of the generated system", Some("10000"))
        .flag("nnz", "non-zeros of the generated system", Some("200000"))
        .flag("dominance", "SPD diagonal dominance, > 1 (near 1 = harder)", Some("1.5"))
        .flag("damping", "PageRank damping factor in [0, 1)", Some("0.85"))
        .flag("tol", "convergence tolerance", Some("1e-6"))
        .flag("max-iters", "iteration budget", Some("300"))
        .flag("seed", "generator seed", Some("42"))
        .bool_flag("scenarios", "run the workload solver scenario set instead")
        .flag("trace", "export the span timeline as Chrome trace-event JSON", None)
        .flag("constants", "sim-constants profile JSON (from `msrep calibrate --save`)", None)
}

/// Dispatch one solver method over a prebuilt system matrix (shared by
/// the flag path and the `--scenarios` path — one copy of the
/// manufactured-rhs convention). CG/Jacobi/PCG solve `A x = b` with
/// `b = A·x*` for a seeded `x*` (PCG with the ILU(0) preconditioner);
/// power iteration runs the transpose (CSC-plan) dispatch like PageRank.
fn dispatch_solver(
    engine: &Engine,
    method: &str,
    mat: &Matrix,
    seed: u64,
    damping: f32,
    cfg: &msrep::solver::SolverConfig,
) -> Result<msrep::solver::SolveReport> {
    match method {
        "cg" | "jacobi" | "pcg" => {
            let x_star = gen::dense_vector(mat.rows(), seed.wrapping_add(1));
            let mut b = vec![0.0f32; mat.rows()];
            msrep::spmv::spmv_matrix(mat, &x_star, 1.0, 0.0, &mut b)?;
            match method {
                "cg" => msrep::solver::cg(engine, mat, &b, cfg),
                "pcg" => msrep::solver::pcg(
                    engine,
                    mat,
                    &b,
                    msrep::solver::Preconditioner::Ilu0,
                    cfg,
                ),
                _ => msrep::solver::jacobi(engine, mat, &b, cfg),
            }
        }
        "pagerank" => msrep::solver::pagerank(engine, mat, damping, cfg),
        "power" => msrep::solver::power_iteration(engine, mat, true, cfg),
        other => Err(Error::Usage(format!("unknown method '{other}'"))),
    }
}

fn cmd_solver_bench(argv: Vec<String>) -> Result<()> {
    let p = solver_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep solver-bench — plan-reusing iterative solvers + amortization report\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = apply_constants(Platform::by_name(&a.str_or("platform", "dgx1"))?, &a)?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let format = FormatKind::parse(&a.str_or("format", "csr"))
        .ok_or_else(|| Error::Usage("bad --format".into()))?;
    let source = msrep::solver::PlanSource::parse(&a.str_or("source", "reused"))
        .ok_or_else(|| Error::Usage("bad --source (expected reused | cold)".into()))?;
    let dominance = a.f64_or("dominance", 1.5)?;
    if dominance <= 1.0 {
        return Err(Error::Usage("--dominance must be > 1 (the SPD certificate is strict)".into()));
    }
    let damping = a.f64_or("damping", 0.85)? as f32;
    let backend = Backend::parse(&a.str_or("backend", "cpu"))
        .ok_or_else(|| Error::Usage("bad --backend (expected cpu | measured)".into()))?;
    let mut engine = Engine::new(RunConfig {
        platform,
        num_gpus,
        mode,
        format,
        backend,
        numa_aware: None,
        strategy_override: None,
    })?;
    let recorder = msrep::obs::TraceRecorder::enabled();
    if a.get("trace").is_some() {
        engine.set_recorder(recorder.clone());
    }
    println!(
        "solver-bench: {} x {} GPUs, mode {}, plan source {}, backend {}\n",
        engine.config().platform.name,
        num_gpus,
        mode.label(),
        source.label(),
        backend.label()
    );

    let mut summary = Table::new([
        "method", "system", "iters", "converged", "residual", "spmv/iter", "cold/iter",
        "amortization",
    ]);
    let mut reports: Vec<msrep::solver::SolveReport> = vec![];

    if a.is_set("scenarios") {
        for s in workload::solver_scenarios() {
            let cfg = msrep::solver::SolverConfig {
                tol: s.tol,
                max_iters: s.max_iters,
                plan_source: source,
            };
            let coo = workload::scenario_matrix(&s);
            let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
            let rep = dispatch_solver(&engine, s.method, &mat, s.seed, damping, &cfg)?;
            println!("== {} ==", s.name);
            print!("{}", msrep::report::render_solver_report(&rep));
            println!();
            push_summary(&mut summary, &rep, s.name.to_string());
            reports.push(rep);
        }
    } else {
        let m = a.usize_or("m", 10_000)?;
        let nnz = a.usize_or("nnz", 200_000)?;
        let seed = a.u64_or("seed", 42)?;
        let cfg = msrep::solver::SolverConfig {
            tol: a.f64_or("tol", 1e-6)?,
            max_iters: a.usize_or("max-iters", 300)?,
            plan_source: source,
        };
        let method_flag = a.str_or("method", "all");
        let methods: Vec<&str> = match method_flag.as_str() {
            "all" => vec!["cg", "pcg", "jacobi", "pagerank", "power"],
            other => vec![other],
        };
        // validate up front so the lazy generators below never run for a typo
        for method in &methods {
            if !matches!(*method, "cg" | "pcg" | "jacobi" | "pagerank" | "power") {
                return Err(Error::Usage(format!(
                    "unknown method '{method}' (expected cg | pcg | jacobi | power | pagerank \
                     | all)"
                )));
            }
        }
        // one matrix per family: cg/jacobi share the certified-SPD system,
        // pcg runs the Poisson stencil its ILU(0) factors are built for
        // (the certified-SPD generator may draw duplicate coordinates,
        // which the zero-fill factorization rejects by contract),
        // pagerank/power share the power-law web graph
        let mut spd_mat: Option<Matrix> = None;
        let mut lap_mat: Option<Matrix> = None;
        let mut graph_mat: Option<Matrix> = None;
        for method in methods {
            let mat: &Matrix = match method {
                "cg" | "jacobi" => spd_mat.get_or_insert_with(|| {
                    to_format(Matrix::Coo(gen::spd(m, nnz, dominance, seed)), format)
                }),
                "pcg" => lap_mat.get_or_insert_with(|| {
                    let grid = (m as f64).sqrt().round().max(2.0) as usize;
                    Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::laplacian_2d(grid))))
                }),
                _ => graph_mat.get_or_insert_with(|| {
                    Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(
                        m, m, nnz, 2.1, seed,
                    ))))
                }),
            };
            let (mm, mnnz) = (mat.rows(), mat.nnz());
            let rep = dispatch_solver(&engine, method, mat, seed, damping, &cfg)?;
            println!("== {method}: {mm} x {mm}, ~{mnnz} nnz ==");
            print!("{}", msrep::report::render_solver_report(&rep));
            println!();
            push_summary(&mut summary, &rep, format!("{mm}x{mm}/{mnnz}"));
            reports.push(rep);
        }
    }

    print!("{}", summary.render());
    if let Some(best) = reports
        .iter()
        .max_by(|a, b| a.amortization().partial_cmp(&b.amortization()).unwrap())
    {
        println!(
            "\nplan reuse: planned-SpMV iteration cost {} < cold-partition iteration cost {} \
             (best amortization {:.2}x on {})",
            format_duration_s(best.planned_iter_cost()),
            format_duration_s(best.cold_iter_cost()),
            best.amortization(),
            best.method,
        );
    }
    if let Some(path) = a.get("trace") {
        export_trace(&recorder, path)?;
    }
    Ok(())
}

/// Append one solve's headline numbers to the cross-method summary table.
fn push_summary(summary: &mut Table, rep: &msrep::solver::SolveReport, system: String) {
    summary.row([
        rep.method.to_string(),
        system,
        rep.iterations.to_string(),
        if rep.converged { "yes" } else { "no" }.to_string(),
        format!("{:.2e}", rep.final_residual),
        format_duration_s(rep.planned_iter_cost()),
        format_duration_s(rep.cold_iter_cost()),
        format!("{:.2}x", rep.amortization()),
    ]);
}

fn spgemm_parser() -> Parser {
    Parser::new()
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag(
            "scenario",
            "scenario name (powerlaw-square | webgraph-square | galerkin-rap | markov-square) \
             or 'all'",
            Some("all"),
        )
        .bool_flag("no-compare", "skip the nnz-balanced planning comparison")
        .flag("trace", "export the span timeline as Chrome trace-event JSON", None)
        .flag("bench-out", "write the per-stage numeric results as a bench JSON", None)
}

fn cmd_spgemm_bench(argv: Vec<String>) -> Result<()> {
    let p = spgemm_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep spgemm-bench — flop-balanced multi-GPU SpGEMM over the scenario chains\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = Platform::by_name(&a.str_or("platform", "dgx1"))?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let mut engine = Engine::new(RunConfig {
        platform,
        num_gpus,
        mode,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })?;
    let recorder = msrep::obs::TraceRecorder::enabled();
    if a.get("trace").is_some() {
        engine.set_recorder(recorder.clone());
    }
    let which = a.str_or("scenario", "all");
    let scenarios: Vec<workload::SpgemmScenario> = if which == "all" {
        workload::spgemm_scenarios()
    } else {
        vec![workload::spgemm_scenario_by_name(&which)
            .ok_or_else(|| Error::Usage(format!("unknown spgemm scenario '{which}'")))?]
    };
    let compare = !a.is_set("no-compare");
    println!(
        "spgemm-bench: {} x {} GPUs, mode {}\n",
        engine.config().platform.name,
        num_gpus,
        mode.label()
    );
    let mut summary = Table::new([
        "scenario",
        "stage",
        "flop imb (nnz plan)",
        "flop imb (flop plan)",
        "numeric (nnz)",
        "numeric (flops)",
        "numeric speedup",
    ]);
    let mut bench_rows: Vec<msrep::util::json::Value> = Vec::new();
    for s in &scenarios {
        let chain = workload::spgemm_scenario_chain(s);
        println!("== {} ({}) ==", s.name, s.kind);
        let mut acc = chain[0].clone();
        for (stage, b) in chain[1..].iter().enumerate() {
            let flop_plan = engine.plan_spgemm(&acc, b)?;
            let rep = engine.spgemm_with_plan(&flop_plan, b)?;
            print!("{}", msrep::report::render_spgemm_report(&rep.metrics));
            if a.get("bench-out").is_some() {
                use msrep::util::json::Value;
                let mut row = std::collections::BTreeMap::new();
                row.insert("scenario".to_string(), Value::Str(s.name.to_string()));
                row.insert("stage".to_string(), Value::Num(stage as f64));
                row.insert(
                    "flop_imbalance".to_string(),
                    Value::Num(rep.metrics.flop_imbalance),
                );
                row.insert("t_symbolic".to_string(), Value::Num(rep.metrics.t_symbolic));
                row.insert("t_numeric".to_string(), Value::Num(rep.metrics.t_numeric));
                row.insert(
                    "modeled_total".to_string(),
                    Value::Num(rep.metrics.modeled_total),
                );
                bench_rows.push(Value::Obj(row));
            }
            if compare {
                let nnz_plan = engine.plan(&acc)?;
                let nnz_rep = engine.spgemm_with_plan(&nnz_plan, b)?;
                summary.row([
                    s.name.to_string(),
                    stage.to_string(),
                    format!("{:.3}", nnz_rep.metrics.flop_imbalance),
                    format!("{:.3}", rep.metrics.flop_imbalance),
                    format_duration_s(nnz_rep.metrics.t_numeric),
                    format_duration_s(rep.metrics.t_numeric),
                    format!(
                        "{:.2}x",
                        msrep::sim::model::speedup(
                            nnz_rep.metrics.t_numeric,
                            rep.metrics.t_numeric
                        )
                    ),
                ]);
            }
            acc = Matrix::Csr(rep.c);
            println!();
        }
    }
    if compare {
        println!(
            "nnz-balanced vs flop-balanced planning (modeled numeric phase = max over GPUs):"
        );
        print!("{}", summary.render());
    }
    if let Some(path) = a.get("trace") {
        export_trace(&recorder, path)?;
    }
    if let Some(path) = a.get("bench-out") {
        use msrep::util::json::Value;
        let mut root = std::collections::BTreeMap::new();
        root.insert(
            "platform".to_string(),
            Value::Str(engine.config().platform.name.clone()),
        );
        root.insert("gpus".to_string(), Value::Num(num_gpus as f64));
        root.insert("mode".to_string(), Value::Str(mode.label().to_string()));
        root.insert("scenarios".to_string(), Value::Arr(bench_rows));
        let rec = msrep::util::bench::bench_record("spgemm_bench", root);
        msrep::util::bench::write_bench_json(path, &rec)?;
        println!("wrote bench trajectory to {path}");
    }
    Ok(())
}

fn sptrsv_parser() -> Parser {
    Parser::new()
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag(
            "scenario",
            "scenario name (ilu0-poisson | powerlaw-lower | banded-lower) or 'all'",
            Some("all"),
        )
        .flag("seed", "right-hand-side seed", Some("42"))
        .bool_flag("no-compare", "skip the naive row-block split comparison")
        .bool_flag("upper", "solve U x = b on the transposed factor instead")
        .flag("trace", "export the span timeline as Chrome trace-event JSON", None)
}

fn cmd_sptrsv_bench(argv: Vec<String>) -> Result<()> {
    let p = sptrsv_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep sptrsv-bench — level-scheduled multi-GPU triangular solves over the \
             scenario factors\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = Platform::by_name(&a.str_or("platform", "dgx1"))?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let seed = a.u64_or("seed", 42)?;
    let mut engine = Engine::new(RunConfig {
        platform,
        num_gpus,
        mode,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })?;
    let recorder = msrep::obs::TraceRecorder::enabled();
    if a.get("trace").is_some() {
        engine.set_recorder(recorder.clone());
    }
    let which = a.str_or("scenario", "all");
    let scenarios: Vec<workload::SptrsvScenario> = if which == "all" {
        workload::sptrsv_scenarios()
    } else {
        vec![workload::sptrsv_scenario_by_name(&which)
            .ok_or_else(|| Error::Usage(format!("unknown sptrsv scenario '{which}'")))?]
    };
    let compare = !a.is_set("no-compare");
    let triangle = if a.is_set("upper") {
        msrep::sptrsv::Triangle::Upper
    } else {
        msrep::sptrsv::Triangle::Lower
    };
    println!(
        "sptrsv-bench: {} x {} GPUs, mode {}, {} solve\n",
        engine.config().platform.name,
        num_gpus,
        mode.label(),
        triangle.label()
    );
    let mut summary = Table::new([
        "scenario",
        "levels",
        "mean par",
        "kernels (rows)",
        "kernels (levels)",
        "speedup",
    ]);
    for s in &scenarios {
        let l = workload::sptrsv_scenario_factor(s);
        let factor = match triangle {
            msrep::sptrsv::Triangle::Lower => Matrix::Csr(l),
            // U = Lᵀ: the same structure solved backward
            msrep::sptrsv::Triangle::Upper => {
                Matrix::Csr(convert::to_csr(&convert::transpose(&Matrix::Csr(l))))
            }
        };
        let b = gen::dense_vector(factor.rows(), seed);
        println!("== {} ({}) ==", s.name, s.kind);
        let plan = engine.plan_sptrsv(&factor, triangle)?;
        let mut rep = engine.sptrsv_with_plan(&plan, &b)?;
        // one-shot attribution: the bench just paid the symbolic pass, so
        // the rendered phase split must charge it (mirrors Engine::sptrsv)
        rep.metrics.t_partition = plan.t_partition;
        rep.metrics.modeled_total += plan.t_partition;
        rep.metrics.measured_partition = plan.measured_partition;
        print!("{}", msrep::report::render_sptrsv_report(&rep.metrics));
        // verify against the sequential sparse oracle
        let expect = msrep::sptrsv::trsv_csr(&convert::to_csr(&factor), &b, triangle)?;
        let max_rel = rep
            .x
            .iter()
            .zip(&expect)
            .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
            .fold(0.0f32, f32::max);
        println!("verify: max relative error vs sequential oracle = {max_rel:.2e}");
        if max_rel > 1e-3 {
            return Err(Error::InvalidMatrix(format!("verification FAILED ({max_rel})")));
        }
        if compare {
            let row_plan = engine.plan_sptrsv_with_split(
                &factor,
                triangle,
                msrep::sptrsv::SptrsvSplit::RowBlocks,
            )?;
            let row_rep = engine.sptrsv_with_plan(&row_plan, &b)?;
            summary.row([
                s.name.to_string(),
                rep.metrics.levels.to_string(),
                format!("{:.1}", rep.metrics.mean_parallelism),
                format_duration_s(row_rep.metrics.t_levels),
                format_duration_s(rep.metrics.t_levels),
                format!(
                    "{:.2}x",
                    msrep::sim::model::speedup(row_rep.metrics.t_levels, rep.metrics.t_levels)
                ),
            ]);
        }
        println!();
    }
    if compare {
        println!(
            "level-balanced vs naive row-block wavefront split \
             (modeled kernel time = Σ levels, max over GPUs):"
        );
        print!("{}", summary.render());
    }
    if let Some(path) = a.get("trace") {
        export_trace(&recorder, path)?;
    }
    Ok(())
}

fn autoplan_parser() -> Parser {
    Parser::new()
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag(
            "scenario",
            "scenario name (banded-stencil | powerlaw-square | tall-skinny | short-wide | \
             block-diagonal) or 'all'",
            Some("all"),
        )
        .flag("reuse", "amortization horizon (expected SpMVs per plan build)", Some("32"))
        .flag("matrix", "MatrixMarket file (tune one matrix instead of the scenarios)", None)
        .flag("suite", "suite matrix name (tune one analog instead of the scenarios)", None)
        .bool_flag("full", "sweep strategies and GPU counts too, not just formats")
}

fn cmd_autoplan_bench(argv: Vec<String>) -> Result<()> {
    let p = autoplan_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep autoplan-bench — profile-driven format auto-tuning vs every fixed format\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = Platform::by_name(&a.str_or("platform", "dgx1"))?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let reuse = a.usize_or("reuse", 32)?.max(1);
    let cfg = RunConfig {
        platform,
        num_gpus,
        mode,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    };
    let engine = Engine::new(cfg.clone())?;
    println!(
        "autoplan-bench: {} x {} GPUs, mode {}, reuse horizon {}\n",
        cfg.platform.name,
        num_gpus,
        mode.label(),
        reuse
    );

    // one ad-hoc matrix, or the whole scenario suite
    let inputs: Vec<(String, Matrix)> = if a.get("matrix").is_some() || a.get("suite").is_some() {
        vec![("input".to_string(), load_matrix(&a)?)]
    } else {
        let which = a.str_or("scenario", "all");
        let scenarios: Vec<workload::AutoplanScenario> = if which == "all" {
            workload::autoplan_scenarios()
        } else {
            vec![workload::autoplan_scenario_by_name(&which)
                .ok_or_else(|| Error::Usage(format!("unknown autoplan scenario '{which}'")))?]
        };
        scenarios
            .iter()
            .map(|s| (s.name.to_string(), Matrix::Coo(workload::autoplan_scenario_matrix(s))))
            .collect()
    };

    if a.is_set("full") {
        // the full sweep is a report, not an acceptance gate: its winners
        // may need a reconfigured engine (np/strategy)
        for (name, mat) in &inputs {
            let opts = msrep::autoplan::AutoPlanOptions::full_sweep(&cfg).with_reuse(reuse);
            let auto = msrep::autoplan::plan_auto(&cfg, mat, &opts)?;
            println!("== {name} (full sweep) ==");
            print!("{}", msrep::report::render_autoplan_report(&auto));
            println!();
        }
        return Ok(());
    }

    let mut summary = Table::new([
        "scenario", "chosen", "auto", "best fixed", "median fixed", "worst fixed",
        "vs median",
    ]);
    let mut median_over_auto: Vec<f64> = Vec::new();
    for (name, mat) in &inputs {
        let opts = msrep::autoplan::AutoPlanOptions::for_config(&cfg).with_reuse(reuse);
        let auto = msrep::autoplan::plan_auto(&cfg, mat, &opts)?;
        println!("== {name} ==");
        print!("{}", msrep::report::render_autoplan_report(&auto));
        println!();

        // the shared acceptance surface (also asserted by
        // benches/autoplan_selection.rs — one definition, two gates)
        let cmp = msrep::autoplan::compare_fixed_formats(&engine, mat, &auto)?;
        summary.row([
            name.clone(),
            auto.choice().candidate.label(),
            format_duration_s(cmp.auto_s),
            format_duration_s(cmp.best()),
            format_duration_s(cmp.median()),
            format_duration_s(cmp.worst()),
            format!("{:.2}x", cmp.vs_median()),
        ]);
        if !cmp.never_worse_than_worst() {
            return Err(Error::Autoplan(format!(
                "ACCEPTANCE FAILED: {name}: auto {:.3e}s worse than the worst fixed \
                 format {:.3e}s",
                cmp.auto_s,
                cmp.worst()
            )));
        }
        median_over_auto.push(cmp.vs_median());
    }
    print!("{}", summary.render());
    let geomean = msrep::util::stats::geomean(&median_over_auto);
    println!(
        "\ntuner vs median fixed format (geomean over {} scenario(s)): {geomean:.2}x",
        median_over_auto.len()
    );
    if median_over_auto.len() > 1 && geomean <= 1.0 {
        return Err(Error::Autoplan(format!(
            "ACCEPTANCE FAILED: tuner does not beat the median fixed format in aggregate \
             (geomean {geomean:.3})"
        )));
    }
    Ok(())
}

fn cluster_parser() -> Parser {
    Parser::new()
        .flag("preset", "summit | dgx1 (node platform + network preset)", Some("summit"))
        .flag("nodes", "comma-separated node counts to sweep", Some("1,2,4,8,16"))
        .flag(
            "scenario",
            "scenario name (powerlaw-cluster | two-band-cluster | banded-cluster) or 'all'",
            Some("all"),
        )
        .bool_flag("quick", "reduced matrix sizes (CI smoke)")
        .flag("trace", "export a traced cluster SpMV as Chrome trace-event JSON", None)
        .flag("out", "write the node-scaling results as a bench JSON", None)
}

fn cmd_cluster_bench(argv: Vec<String>) -> Result<()> {
    use msrep::coordinator::{scaleout_spmv, ClusterEngine, NodeSplit, ScaleOutScheme};
    use msrep::sim::Cluster;
    use msrep::util::json::Value;

    let p = cluster_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep cluster-bench — two-tier scale-out sweep: MSREP partial-merge vs \
             broadcast[39], topology-aware vs topology-blind node splits, memoized \
             CommPlans (DESIGN.md §16)\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let preset = a.str_or("preset", "summit");
    let cluster_of = |n: usize| -> Result<Cluster> {
        match preset.as_str() {
            "summit" => Ok(Cluster::summit(n)),
            "dgx1" => Ok(Cluster::dgx1_pod(n)),
            other => Err(Error::Usage(format!("unknown preset '{other}' (summit | dgx1)"))),
        }
    };
    let nodes: Vec<usize> = a
        .str_or("nodes", "1,2,4,8,16")
        .split(',')
        .map(|t| {
            t.trim()
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .ok_or_else(|| Error::Usage(format!("--nodes: bad node count '{t}'")))
        })
        .collect::<Result<Vec<_>>>()?;
    let quick = a.is_set("quick");
    let which = a.str_or("scenario", "all");
    let mut scenarios = if which == "all" {
        workload::scaleout_scenarios()
    } else {
        vec![workload::scaleout_scenario_by_name(&which)
            .ok_or_else(|| Error::Usage(format!("unknown scaleout scenario '{which}'")))?]
    };
    if quick {
        for s in &mut scenarios {
            s.m /= 4;
            s.nnz /= 4;
        }
    }
    // validate the preset once up front so a bad name fails before work
    cluster_of(1)?;
    println!(
        "cluster-bench: preset {preset}, nodes {nodes:?}, {} scenario(s){}\n",
        scenarios.len(),
        if quick { " (quick)" } else { "" }
    );

    let node_run_config = |cluster: &Cluster| RunConfig {
        platform: cluster.node.clone(),
        num_gpus: cluster.node.num_gpus,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        ..Default::default()
    };

    let mut bench_scenarios: Vec<Value> = Vec::new();
    for s in &scenarios {
        let csr = workload::scaleout_scenario_matrix(s);
        println!("== {} ({} x {}, {} nnz) ==", s.name, csr.rows(), csr.cols(), csr.nnz());
        let mut ms = Vec::with_capacity(nodes.len());
        let mut bc = Vec::with_capacity(nodes.len());
        let mut points: Vec<Value> = Vec::new();
        for &n in &nodes {
            let cluster = cluster_of(n)?;
            let rep_ms = scaleout_spmv(&cluster, &csr, ScaleOutScheme::MsrepPartialMerge)?;
            let rep_bc = scaleout_spmv(&cluster, &csr, ScaleOutScheme::BroadcastAllGather)?;
            for (scheme, rep) in [
                (ScaleOutScheme::MsrepPartialMerge, &rep_ms),
                (ScaleOutScheme::BroadcastAllGather, &rep_bc),
            ] {
                let mut row = std::collections::BTreeMap::new();
                row.insert("scheme".to_string(), Value::Str(scheme.label().to_string()));
                row.insert("nodes".to_string(), Value::Num(n as f64));
                row.insert("t_intra".to_string(), Value::Num(rep.t_intra));
                row.insert("t_network".to_string(), Value::Num(rep.t_network));
                row.insert("total".to_string(), Value::Num(rep.total));
                row.insert(
                    "net_ingest_bytes".to_string(),
                    Value::Num(rep.net_ingest_bytes as f64),
                );
                row.insert(
                    "node_loads".to_string(),
                    Value::Arr(rep.node_loads.iter().map(|&l| Value::Num(l as f64)).collect()),
                );
                points.push(Value::Obj(row));
            }
            ms.push(rep_ms);
            bc.push(rep_bc);
        }
        print!("{}", msrep::report::render_scaleout_report(&nodes, &ms, &bc));

        // topology-aware vs blind level-0 split + CommPlan memoization, at
        // the largest multi-node count of the sweep
        let mut topology = std::collections::BTreeMap::new();
        if let Some(&n) = nodes.iter().filter(|&&n| n > 1).max() {
            let cluster = cluster_of(n)?;
            let ce = ClusterEngine::new(cluster.clone(), node_run_config(&cluster))?;
            let aware = ce.plan_with_split(&csr, NodeSplit::TopologyAware)?;
            let reuse = ce.plan_with_split(&csr, NodeSplit::TopologyAware)?;
            let blind = ce.plan_with_split(&csr, NodeSplit::NnzBalanced)?;
            let aware_t = ce.model_spmv(&aware)?.t_intra;
            let blind_t = ce.model_spmv(&blind)?.t_intra;
            let stats = ce.comm_stats();
            println!(
                "level-0 split at {n} nodes (modeled max-node replay): \
                 topology-aware {} vs nnz-balanced {} ({:+.2}%)",
                format_duration_s(aware_t),
                format_duration_s(blind_t),
                (aware_t / blind_t - 1.0) * 100.0,
            );
            println!(
                "comm-plan cache: {} misses (one schedule per split), {} hit(s) \
                 (re-plan {} the memoized schedule)\n",
                stats.misses,
                stats.hits,
                if reuse.comm_cached { "reused" } else { "MISSED" },
            );
            topology.insert("nodes".to_string(), Value::Num(n as f64));
            topology.insert("aware_t_intra".to_string(), Value::Num(aware_t));
            topology.insert("blind_t_intra".to_string(), Value::Num(blind_t));
            topology.insert("comm_hits".to_string(), Value::Num(stats.hits as f64));
            topology.insert("comm_misses".to_string(), Value::Num(stats.misses as f64));
            topology.insert("reuse_cached".to_string(), Value::Bool(reuse.comm_cached));
        }

        let mut rec = std::collections::BTreeMap::new();
        rec.insert("scenario".to_string(), Value::Str(s.name.to_string()));
        rec.insert("m".to_string(), Value::Num(csr.rows() as f64));
        rec.insert("nnz".to_string(), Value::Num(csr.nnz() as f64));
        rec.insert("points".to_string(), Value::Arr(points));
        rec.insert("topology".to_string(), Value::Obj(topology));
        bench_scenarios.push(Value::Obj(rec));
    }

    if let Some(path) = a.get("trace") {
        // one traced topology-aware cluster SpMV at the largest node count
        let recorder = msrep::obs::TraceRecorder::enabled();
        let cluster = cluster_of(nodes.iter().copied().max().unwrap_or(1))?;
        let mut ce = ClusterEngine::new(cluster.clone(), node_run_config(&cluster))?;
        ce.set_recorder(recorder.clone());
        let csr = workload::scaleout_scenario_matrix(&scenarios[0]);
        let x = gen::dense_vector(csr.cols(), 3);
        ce.spmv(&csr, &x, 1.0, 0.0, None)?;
        export_trace(&recorder, path)?;
    }

    if let Some(path) = a.get("out") {
        let mut root = std::collections::BTreeMap::new();
        root.insert("preset".to_string(), Value::Str(preset.clone()));
        root.insert(
            "nodes".to_string(),
            Value::Arr(nodes.iter().map(|&n| Value::Num(n as f64)).collect()),
        );
        root.insert("quick".to_string(), Value::Bool(quick));
        root.insert("scenarios".to_string(), Value::Arr(bench_scenarios));
        let rec = msrep::util::bench::bench_record("scaleout", root);
        msrep::util::bench::write_bench_json(path, &rec)?;
        println!("wrote bench record to {path}");
    }
    Ok(())
}

fn trace_parser() -> Parser {
    Parser::new()
        .flag("scenario", "small | medium (sizes every stage of the traced tour)", Some("small"))
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag("out", "Chrome trace-event JSON output path", Some("trace.json"))
        .flag("jsonl", "also write the span stream as JSONL to this path", None)
        .flag("bench-out", "write the metrics registry as a bench-trajectory JSON", None)
        .flag("width", "ASCII Gantt width in cells", Some("72"))
        .flag("seed", "generator seed", Some("42"))
}

fn cmd_trace(argv: Vec<String>) -> Result<()> {
    let p = trace_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep trace — traced tour of every subsystem with span-timeline export\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = Platform::by_name(&a.str_or("platform", "dgx1"))?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let seed = a.u64_or("seed", 42)?;
    let width = a.usize_or("width", 72)?;
    let scenario = a.str_or("scenario", "small");
    let (m, nnz, requests) = match scenario.as_str() {
        "small" => (512usize, 6_000usize, 32usize),
        "medium" => (2_048, 40_000, 96),
        other => {
            return Err(Error::Usage(format!(
                "unknown trace scenario '{other}' (expected small | medium)"
            )))
        }
    };
    let cfg = RunConfig {
        platform,
        num_gpus,
        mode,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    };
    println!(
        "trace: scenario {scenario} ({m} x {m}, ~{nnz} nnz), {} x {num_gpus} GPUs, mode {}\n",
        cfg.platform.name,
        mode.label()
    );

    let recorder = msrep::obs::TraceRecorder::enabled();
    let mut registry = msrep::obs::MetricsRegistry::new();
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(m, m, nnz, 2.0, seed))));

    // 1. serving first: its spans sit on the modeled arrival clock starting
    // at zero, and the shared cursor then carries the one-shot ops past the
    // last dispatch so the lanes stay disjoint in time
    let serve_cfg = msrep::serve::ServeConfig {
        run: cfg.clone(),
        num_engines: 2,
        max_batch: 4,
        flush_deadline_s: 100e-6,
        queue_capacity: 64,
        plan_cache_capacity: 8,
        cluster: None,
    };
    let mut server = msrep::serve::Server::new(serve_cfg)?;
    server.set_recorder(&recorder);
    let tenants = vec![server.register(mat.clone())];
    let reqs = serve_trace(&tenants, m, requests, 200_000.0, None, seed);
    let serve_rep = server.run(reqs)?;
    registry.record_serve("serve", &serve_rep);

    // 2. the one-shot engine ops, on device lanes past the serve pool's
    let mut engine = Engine::new(cfg.clone())?;
    engine.set_recorder(recorder.with_gpu_base(2 * num_gpus));
    let x = gen::dense_vector(m, 7);
    let spmv_rep = engine.spmv(&mat, &x, 1.0, 0.0, None)?;
    registry.record_spmv("spmv", &spmv_rep.metrics);
    let spgemm_rep = engine.spgemm(&mat, &mat)?;
    registry.record_spgemm("spgemm", &spgemm_rep.metrics);
    let lower = Matrix::Csr(msrep::sptrsv::triangular_of(
        &mat,
        msrep::sptrsv::Triangle::Lower,
        1.0,
    ));
    let b = gen::dense_vector(m, 11);
    let sptrsv_rep = engine.sptrsv(&lower, &b, msrep::sptrsv::Triangle::Lower)?;
    registry.record_sptrsv("sptrsv", &sptrsv_rep.metrics);

    // 3. one plan-reusing CG solve (iteration spans over the engine spans)
    let spd = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(m, nnz, 2.0, seed))));
    let x_star = gen::dense_vector(m, 13);
    let mut rhs = vec![0.0f32; m];
    msrep::spmv::spmv_matrix(&spd, &x_star, 1.0, 0.0, &mut rhs)?;
    let solver_cfg = msrep::solver::SolverConfig {
        tol: 1e-6,
        max_iters: 60,
        plan_source: msrep::solver::PlanSource::Reused,
    };
    let solve_rep = msrep::solver::cg(&engine, &spd, &rhs, &solver_cfg)?;
    registry.record_solve("solver.cg", &solve_rep);

    let trace = recorder.take();
    print!("{}", msrep::obs::render_gantt(&trace, width));
    println!();
    print!("{}", registry.render());

    let out = a.str_or("out", "trace.json");
    msrep::obs::write_chrome_trace(&trace, &out)?;
    println!(
        "\nwrote Chrome trace ({} spans, {} tracks, envelope {}) to {out}",
        trace.len(),
        trace.tracks().len(),
        format_duration_s(trace.envelope()),
    );
    if let Some(path) = a.get("jsonl") {
        msrep::obs::write_jsonl(&trace, path)?;
        println!("wrote JSONL span stream to {path}");
    }
    if let Some(path) = a.get("bench-out") {
        use msrep::util::json::Value;
        let mut root = std::collections::BTreeMap::new();
        root.insert("scenario".to_string(), Value::Str(scenario.clone()));
        root.insert("platform".to_string(), Value::Str(cfg.platform.name.to_string()));
        root.insert("gpus".to_string(), Value::Num(num_gpus as f64));
        root.insert("mode".to_string(), Value::Str(mode.label().to_string()));
        root.insert("spans".to_string(), Value::Num(trace.len() as f64));
        root.insert("envelope_s".to_string(), Value::Num(trace.envelope()));
        root.insert("metrics".to_string(), registry.to_json());
        let rec = msrep::util::bench::bench_record("obs_baseline", root);
        msrep::util::bench::write_bench_json(path, &rec)?;
        println!("wrote bench trajectory to {path}");
    }
    Ok(())
}

/// Drain a recorder and export its trace as Chrome trace-event JSON — the
/// shared tail of every bench subcommand's `--trace` flag.
fn export_trace(recorder: &msrep::obs::TraceRecorder, path: &str) -> Result<()> {
    let trace = recorder.take();
    msrep::obs::write_chrome_trace(&trace, path)?;
    println!(
        "wrote Chrome trace ({} spans, {} tracks) to {path}",
        trace.len(),
        trace.tracks().len()
    );
    Ok(())
}

fn calibrate_parser() -> Parser {
    Parser::new()
        .flag("np", "comma-separated GPU counts to replay", Some("1,2,4,8"))
        .flag("k", "SpMM right-hand sides", Some("8"))
        .flag("out", "calibration report JSON path", Some("BENCH_calibration.json"))
        .flag(
            "save",
            "also write the fitted constants alone, as a `--constants` profile",
            None,
        )
        .bool_flag("quick", "smoke grid: 2 SpMV suite entries, 1 SpMM entry")
}

fn cmd_calibrate(argv: Vec<String>) -> Result<()> {
    let p = calibrate_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep calibrate — fit the sim constants against measured-backend walls\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let np_grid: Vec<usize> = a
        .str_or("np", "1,2,4,8")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| Error::Usage(format!("bad --np entry '{s}'")))
        })
        .collect::<Result<_>>()?;
    if np_grid.is_empty() {
        return Err(Error::Usage("--np needs at least one GPU count".into()));
    }
    let opts = msrep::exec::calibrate::CalibrationOptions {
        np_grid,
        quick: a.is_set("quick"),
        spmm_k: a.usize_or("k", 8)?.max(1),
        nnz_scale: 1.0,
    };
    println!(
        "calibrate: dgx1, mode p*, measured backend, np {:?}{}\n",
        opts.np_grid,
        if opts.quick { " (quick grid)" } else { "" }
    );
    let report = msrep::exec::calibrate::calibrate(&opts)?;
    print!("{}", report.render());
    let out = a.str_or("out", "BENCH_calibration.json");
    std::fs::write(&out, report.to_json())?;
    println!("wrote calibration report to {out}");
    if let Some(path) = a.get("save") {
        std::fs::write(path, report.fitted.to_json())?;
        println!("wrote fitted constants profile to {path} (use with --constants)");
    }
    Ok(())
}

fn perf_parser() -> Parser {
    Parser::new()
        .flag("suite", "quick | full (pinned scenario suite variant)", Some("quick"))
        .flag("reps", "replays per op (median + MAD reduction)", Some("5"))
        .flag("platform", "summit | dgx1", Some("dgx1"))
        .flag("gpus", "GPUs to use", None)
        .flag("mode", "baseline | pstar | popt", Some("popt"))
        .flag("constants", "sim-constants profile JSON (from `msrep calibrate --save`)", None)
        .flag("out", "history JSONL the record is appended to", Some("BENCH_history.jsonl"))
        .flag("record", "also write the record as a standalone JSON document", None)
        .flag("against", "baseline record (.json, or .jsonl whose last line is used)", None)
        .flag("k-sigma", "measured gate: MAD-sigma multiplier", Some("8.0"))
        .flag("rel-floor", "measured gate: relative floor vs the baseline median", Some("0.25"))
        .flag("abs-floor-us", "measured gate: absolute floor in microseconds", Some("2000"))
        .bool_flag("warn-only", "report measured regressions without failing the gate")
        .bool_flag("no-history", "skip appending the record to the history file")
}

fn cmd_perf(argv: Vec<String>) -> Result<()> {
    let p = perf_parser();
    if argv.iter().any(|a| a == "--help") {
        println!(
            "msrep perf — continuous perf suite: median+MAD record, noise-gated \
             baseline comparison, span-level regression attribution\n{}",
            p.help()
        );
        return Ok(());
    }
    let a = p.parse(argv)?;
    let platform = apply_constants(Platform::by_name(&a.str_or("platform", "dgx1"))?, &a)?;
    let num_gpus = a.usize_or("gpus", platform.num_gpus)?;
    let mode = Mode::parse(&a.str_or("mode", "popt"))
        .ok_or_else(|| Error::Usage("bad --mode".into()))?;
    let opts = msrep::perf::PerfOptions {
        platform,
        num_gpus,
        mode,
        suite: a.str_or("suite", "quick"),
        reps: a.usize_or("reps", 5)?.max(1),
    };
    let spec = msrep::perf::suite::spec(&opts.suite)
        .ok_or_else(|| Error::Usage(format!("unknown perf suite '{}' (quick | full)", opts.suite)))?;
    println!(
        "perf: suite {} on {} x {num_gpus} GPUs, mode {}, {} reps\n",
        spec.name,
        opts.platform.name,
        mode.label(),
        opts.reps,
    );
    // workloads are built once and reused for regression attribution, so
    // the traced re-run replays bit-identical inputs
    let w = msrep::perf::Workloads::build(&spec)?;
    // read the baseline BEFORE appending: `--against BENCH_history.jsonl
    // --out BENCH_history.jsonl` must gate against the previous run's
    // record, not the one this run is about to append
    let base = match a.get("against") {
        Some(path) => Some(msrep::perf::PerfRecord::from_value(
            &msrep::util::bench::read_last_bench_record(path)?,
        )?),
        None => None,
    };
    let record = msrep::perf::run_suite_on(&opts, &w)?;
    print!("{}", msrep::report::render_perf_record(&record));
    let value = record.to_value();
    if !a.is_set("no-history") {
        let out = a.str_or("out", "BENCH_history.jsonl");
        msrep::util::bench::append_bench_jsonl(&out, &value)?;
        println!("appended record to {out}");
    }
    if let Some(path) = a.get("record") {
        msrep::util::bench::write_bench_json(path, &value)?;
        println!("wrote record to {path}");
    }
    let (Some(base), Some(base_path)) = (base, a.get("against")) else {
        return Ok(());
    };
    let gate = msrep::perf::GateConfig {
        k_sigma: a.f64_or("k-sigma", 8.0)?,
        rel_floor: a.f64_or("rel-floor", 0.25)?,
        abs_floor_s: a.f64_or("abs-floor-us", 2000.0)? * 1e-6,
    };
    let cmp = msrep::perf::compare(&base, &record, &gate)?;
    println!();
    print!("{}", msrep::report::render_comparison(&cmp));
    let mut attributed: Vec<String> = Vec::new();
    for f in cmp.gating() {
        if f.kind == msrep::perf::FindingKind::MeasuredRegression && !attributed.contains(&f.op) {
            attributed.push(f.op.clone());
            println!();
            print!(
                "{}",
                msrep::perf::attribution::attribute(f, &w, &opts.platform, num_gpus, mode)?
            );
        }
    }
    if !cmp.passed() {
        let drift = cmp
            .gating()
            .iter()
            .any(|f| f.kind == msrep::perf::FindingKind::ModeledDrift);
        if drift || !a.is_set("warn-only") {
            return Err(Error::Perf(format!(
                "gate FAILED: {} finding(s) past the noise threshold vs {base_path}",
                cmp.gating().len()
            )));
        }
        println!("(measured regressions reported as warnings only: --warn-only)");
    }
    Ok(())
}

fn cmd_suite() -> Result<()> {
    let mut t = Table::new(["matrix", "paper size", "paper nnz", "R", "scaled m", "scaled nnz"]);
    for e in workload::suite() {
        t.row([
            e.name.to_string(),
            format!("{}K x {}K", e.paper_m / 1000, e.paper_m / 1000),
            format!("{}M", e.paper_nnz / 1_000_000),
            format!("{:.2}", e.r),
            e.m.to_string(),
            e.nnz.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
