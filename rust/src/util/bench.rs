//! Micro/meso benchmark harness (replaces criterion, unavailable offline).
//!
//! Used by every target under `rust/benches/` (declared `harness = false`).
//! Auto-calibrates the iteration count to a time budget, reports
//! mean/σ/min/p95, and supports the before/after comparisons the §Perf log
//! records.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark's collected samples + summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// benchmark id ("fig16/summit/csr/baseline")
    pub name: String,
    /// per-iteration seconds
    pub summary: Summary,
    /// iterations actually run
    pub iters: usize,
}

impl BenchResult {
    /// One-line human rendering.
    pub fn render(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (σ {:>10}, min {:>10}, p95 {:>10}, n={})",
            self.name,
            crate::report::format_duration_s(self.summary.mean),
            crate::report::format_duration_s(self.summary.std_dev),
            crate::report::format_duration_s(self.summary.min),
            crate::report::format_duration_s(self.summary.p95),
            self.iters,
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// target measurement time per benchmark (seconds)
    pub budget_s: f64,
    /// warm-up iterations before sampling
    pub warmup: usize,
    /// max samples to collect
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // modest defaults: the figure benches sweep many configurations
        Bench { budget_s: 0.6, warmup: 1, max_samples: 25 }
    }
}

impl Bench {
    /// Quick harness for CI-ish runs (`MSREP_BENCH_QUICK=1`).
    pub fn from_env() -> Bench {
        if std::env::var("MSREP_BENCH_QUICK").is_ok() {
            Bench { budget_s: 0.05, warmup: 0, max_samples: 3 }
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, auto-scaling iterations into the budget. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        // pilot to size the sample count
        let t0 = Instant::now();
        black_box(f());
        let pilot = t0.elapsed().as_secs_f64().max(1e-9);
        let want = ((self.budget_s / pilot) as usize).clamp(1, self.max_samples);
        let mut samples = Vec::with_capacity(want + 1);
        samples.push(pilot);
        for _ in 0..want {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters: samples.len(),
        }
    }
}

/// Optimization-barrier identity (stable-rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench-section header (keeps `cargo bench` output scannable).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_samples_within_bounds() {
        let b = Bench { budget_s: 0.02, warmup: 1, max_samples: 10 };
        let mut count = 0u64;
        let r = b.run("noop", || {
            count += 1;
            count
        });
        assert!(r.iters >= 2 && r.iters <= 11, "iters {}", r.iters);
        assert!(r.summary.mean >= 0.0);
        assert!(count as usize >= r.iters);
    }

    #[test]
    fn slow_benchmark_runs_once_plus_pilot() {
        let b = Bench { budget_s: 0.0, warmup: 0, max_samples: 25 };
        let r = b.run("slow", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.iters <= 2 + 1);
    }

    #[test]
    fn render_contains_name_and_mean() {
        let b = Bench { budget_s: 0.01, warmup: 0, max_samples: 3 };
        let r = b.run("my_bench", || 42);
        let s = r.render();
        assert!(s.contains("my_bench"));
        assert!(s.contains("/iter"));
    }
}
