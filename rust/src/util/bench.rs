//! Micro/meso benchmark harness (replaces criterion, unavailable offline)
//! plus the canonical `BENCH_*` artifact writer.
//!
//! The harness half is used by every target under `rust/benches/`
//! (declared `harness = false`): it auto-calibrates the iteration count to
//! a time budget, reports mean/σ/min/p95, and supports the before/after
//! comparisons the §Perf log records.
//!
//! The writer half ([`bench_record`] / [`write_bench_json`] /
//! [`append_bench_jsonl`]) is the **single** serialization path for every
//! `BENCH_*` artifact the repo emits — the obs baseline, the calibration
//! report, the SpGEMM bench trajectory and the perf observatory's
//! `BENCH_history.jsonl` all share one schema-versioned envelope
//! (`{"schema": "msrep-bench-v1", "bench": "<name>", ...}`) with
//! BTreeMap-sorted keys, so records stay byte-stable and diffable
//! (DESIGN.md §15).

use std::collections::BTreeMap;
use std::io::Write as _;
use std::time::Instant;

use super::json::{self, Value};
use super::stats::Summary;
use crate::error::{Error, Result};

/// Schema tag stamped into every `BENCH_*` artifact envelope.
pub const BENCH_SCHEMA: &str = "msrep-bench-v1";

/// Wrap payload fields into the canonical bench envelope: a JSON object
/// carrying `schema` ([`BENCH_SCHEMA`]) and `bench` (the record family,
/// e.g. `"calibration"` or `"perf_suite"`) plus the payload, keys sorted.
///
/// Reserved keys (`schema`, `bench`) in the payload are overwritten — the
/// envelope owns them.
pub fn bench_record(bench: &str, mut fields: BTreeMap<String, Value>) -> Value {
    fields.insert("schema".to_string(), Value::Str(BENCH_SCHEMA.to_string()));
    fields.insert("bench".to_string(), Value::Str(bench.to_string()));
    Value::Obj(fields)
}

/// Write one bench record as a compact JSON document.
pub fn write_bench_json(path: &str, record: &Value) -> Result<()> {
    std::fs::write(path, record.to_json()).map_err(Error::Io)
}

/// Append one bench record as a single JSONL line (creating the file if
/// needed) — the `BENCH_history.jsonl` trajectory writer.
pub fn append_bench_jsonl(path: &str, record: &Value) -> Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(Error::Io)?;
    writeln!(f, "{}", record.to_json()).map_err(Error::Io)
}

/// Parse the last non-empty line of a JSONL trajectory (the most recent
/// record). Accepts a plain single-record `.json` document too, so
/// baseline flags can point at either artifact shape.
pub fn read_last_bench_record(path: &str) -> Result<Value> {
    let text = std::fs::read_to_string(path).map_err(Error::Io)?;
    let last = text
        .lines()
        .rev()
        .find(|l| !l.trim().is_empty())
        .ok_or_else(|| Error::Usage(format!("{path}: empty bench file")))?;
    // a pretty-printed or single-record .json is not line-delimited; fall
    // back to parsing the whole document
    json::parse(last).or_else(|_| json::parse(&text))
}

/// One benchmark's collected samples + summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// benchmark id ("fig16/summit/csr/baseline")
    pub name: String,
    /// per-iteration seconds
    pub summary: Summary,
    /// iterations actually run
    pub iters: usize,
}

impl BenchResult {
    /// One-line human rendering.
    pub fn render(&self) -> String {
        format!(
            "{:<48} {:>12}/iter  (σ {:>10}, min {:>10}, p95 {:>10}, n={})",
            self.name,
            crate::report::format_duration_s(self.summary.mean),
            crate::report::format_duration_s(self.summary.std_dev),
            crate::report::format_duration_s(self.summary.min),
            crate::report::format_duration_s(self.summary.p95),
            self.iters,
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bench {
    /// target measurement time per benchmark (seconds)
    pub budget_s: f64,
    /// warm-up iterations before sampling
    pub warmup: usize,
    /// max samples to collect
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        // modest defaults: the figure benches sweep many configurations
        Bench { budget_s: 0.6, warmup: 1, max_samples: 25 }
    }
}

impl Bench {
    /// Quick harness for CI-ish runs (`MSREP_BENCH_QUICK=1`).
    pub fn from_env() -> Bench {
        if std::env::var("MSREP_BENCH_QUICK").is_ok() {
            Bench { budget_s: 0.05, warmup: 0, max_samples: 3 }
        } else {
            Bench::default()
        }
    }

    /// Measure `f`, auto-scaling iterations into the budget. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        // pilot to size the sample count
        let t0 = Instant::now();
        black_box(f());
        let pilot = t0.elapsed().as_secs_f64().max(1e-9);
        let want = ((self.budget_s / pilot) as usize).clamp(1, self.max_samples);
        let mut samples = Vec::with_capacity(want + 1);
        samples.push(pilot);
        for _ in 0..want {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters: samples.len(),
        }
    }
}

/// Optimization-barrier identity (stable-rust black box).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a bench-section header (keeps `cargo bench` output scannable).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_record_pins_canonical_key_order() {
        // keys inserted out of order must serialize sorted, with the
        // envelope's schema/bench fields folded in — byte-stable diffs
        let mut fields = BTreeMap::new();
        fields.insert("zeta".to_string(), Value::Num(1.0));
        fields.insert("alpha".to_string(), Value::Str("x".to_string()));
        let rec = bench_record("unit", fields);
        assert_eq!(
            rec.to_json(),
            r#"{"alpha":"x","bench":"unit","schema":"msrep-bench-v1","zeta":1}"#
        );
    }

    #[test]
    fn bench_record_round_trips_byte_stable() {
        let mut fields = BTreeMap::new();
        fields.insert("n".to_string(), Value::Num(3.0));
        let mut nested = BTreeMap::new();
        nested.insert("b".to_string(), Value::Num(2.5));
        nested.insert("a".to_string(), Value::Arr(vec![Value::Bool(true), Value::Null]));
        fields.insert("payload".to_string(), Value::Obj(nested));
        let rec = bench_record("unit", fields);
        let once = rec.to_json();
        let twice = json::parse(&once).unwrap().to_json();
        assert_eq!(once, twice, "parse → serialize must be the identity");
    }

    #[test]
    fn bench_record_owns_the_envelope_keys() {
        let mut fields = BTreeMap::new();
        fields.insert("schema".to_string(), Value::Str("bogus".to_string()));
        let rec = bench_record("unit", fields);
        assert_eq!(rec.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert_eq!(rec.get("bench").unwrap().as_str(), Some("unit"));
    }

    #[test]
    fn jsonl_append_and_read_last() {
        let dir = std::env::temp_dir().join("msrep_bench_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hist.jsonl");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        for i in 0..3 {
            let mut fields = BTreeMap::new();
            fields.insert("i".to_string(), Value::Num(i as f64));
            append_bench_jsonl(path, &bench_record("unit", fields)).unwrap();
        }
        let last = read_last_bench_record(path).unwrap();
        assert_eq!(last.get("i").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn read_last_accepts_single_record_json() {
        let dir = std::env::temp_dir().join("msrep_bench_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("single.json");
        let path = path.to_str().unwrap();
        let rec = bench_record("unit", BTreeMap::new());
        write_bench_json(path, &rec).unwrap();
        assert_eq!(read_last_bench_record(path).unwrap(), rec);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn run_collects_samples_within_bounds() {
        let b = Bench { budget_s: 0.02, warmup: 1, max_samples: 10 };
        let mut count = 0u64;
        let r = b.run("noop", || {
            count += 1;
            count
        });
        assert!(r.iters >= 2 && r.iters <= 11, "iters {}", r.iters);
        assert!(r.summary.mean >= 0.0);
        assert!(count as usize >= r.iters);
    }

    #[test]
    fn slow_benchmark_runs_once_plus_pilot() {
        let b = Bench { budget_s: 0.0, warmup: 0, max_samples: 25 };
        let r = b.run("slow", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.iters <= 2 + 1);
    }

    #[test]
    fn render_contains_name_and_mean() {
        let b = Bench { budget_s: 0.01, warmup: 0, max_samples: 3 };
        let r = b.run("my_bench", || 42);
        let s = r.render();
        assert!(s.contains("my_bench"));
        assert!(s.contains("/iter"));
    }
}
