//! Declarative command-line flag parser (replaces `clap`, unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, per-command help text, and typed accessors with defaults.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Specification of one flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    /// long name without the leading `--`
    pub name: &'static str,
    /// help text
    pub help: &'static str,
    /// true if the flag takes no value
    pub is_bool: bool,
    /// printable default (for help only)
    pub default: Option<&'static str>,
}

/// A parsed command line: flag values + positionals.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    /// positional arguments in order
    pub positional: Vec<String>,
}

/// Flag-set builder + parser.
#[derive(Debug, Default)]
pub struct Parser {
    specs: Vec<FlagSpec>,
}

impl Parser {
    /// Empty parser.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a value-taking flag.
    pub fn flag(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.specs.push(FlagSpec { name, help, is_bool: false, default });
        self
    }

    /// Register a boolean flag.
    pub fn bool_flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, is_bool: true, default: None });
        self
    }

    fn spec(&self, name: &str) -> Option<&FlagSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Render a help block listing all registered flags.
    pub fn help(&self) -> String {
        let mut out = String::new();
        for s in &self.specs {
            let mut line = format!("  --{}", s.name);
            if !s.is_bool {
                line.push_str(" <value>");
            }
            while line.len() < 28 {
                line.push(' ');
            }
            line.push_str(s.help);
            if let Some(d) = s.default {
                line.push_str(&format!(" [default: {d}]"));
            }
            out.push_str(&line);
            out.push('\n');
        }
        out
    }

    /// Parse a token stream (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .spec(&name)
                    .ok_or_else(|| Error::Usage(format!("unknown flag --{name}")))?;
                let value = if spec.is_bool {
                    if inline_val.is_some() {
                        return Err(Error::Usage(format!("--{name} takes no value")));
                    }
                    "true".to_string()
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| Error::Usage(format!("--{name} needs a value")))?,
                    }
                };
                args.flags.insert(name, value);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }
}

impl Args {
    /// Raw string value of a flag, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String value with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Boolean flag presence.
    pub fn is_set(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    /// Typed usize flag.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    /// Typed u64 flag.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    /// Typed f64 flag.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("--{name}: expected float, got '{v}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(toks: &[&str]) -> Vec<String> {
        toks.iter().map(|s| s.to_string()).collect()
    }

    fn parser() -> Parser {
        Parser::new()
            .flag("gpus", "number of gpus", Some("8"))
            .flag("alpha", "scale", Some("1.0"))
            .bool_flag("verbose", "chatty output")
    }

    #[test]
    fn parse_separate_and_inline_values() {
        let a = parser().parse(argv(&["--gpus", "4", "--alpha=2.5"])).unwrap();
        assert_eq!(a.usize_or("gpus", 8).unwrap(), 4);
        assert_eq!(a.f64_or("alpha", 1.0).unwrap(), 2.5);
    }

    #[test]
    fn defaults_apply_when_absent() {
        let a = parser().parse(argv(&[])).unwrap();
        assert_eq!(a.usize_or("gpus", 8).unwrap(), 8);
        assert!(!a.is_set("verbose"));
    }

    #[test]
    fn bool_flag_and_positionals() {
        let a = parser().parse(argv(&["run", "--verbose", "file.mtx"])).unwrap();
        assert!(a.is_set("verbose"));
        assert_eq!(a.positional, vec!["run", "file.mtx"]);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(matches!(
            parser().parse(argv(&["--nope"])),
            Err(Error::Usage(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parser().parse(argv(&["--gpus"])).is_err());
    }

    #[test]
    fn bool_with_value_rejected() {
        assert!(parser().parse(argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn bad_type_reports_flag_name() {
        let a = parser().parse(argv(&["--gpus", "many"])).unwrap();
        match a.usize_or("gpus", 1) {
            Err(Error::Usage(msg)) => assert!(msg.contains("gpus")),
            other => panic!("expected usage error, got {other:?}"),
        }
    }

    #[test]
    fn help_lists_flags() {
        let h = parser().help();
        assert!(h.contains("--gpus") && h.contains("default: 8"));
        assert!(h.contains("--verbose"));
    }
}
