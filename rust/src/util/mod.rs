//! Standard-library substrates.
//!
//! The build environment is offline and only the `xla` crate's vendored
//! dependency closure is available (DESIGN.md §3), so the usual ecosystem
//! crates (rand, serde, clap, criterion, proptest) are replaced by small,
//! tested in-crate implementations:
//!
//! * [`rng`]   — splitmix64 / xoshiro256++ PRNG (replaces `rand`)
//! * [`json`]  — minimal JSON value parser + writer (replaces `serde_json`,
//!   used for the artifact manifest)
//! * [`cli`]   — declarative flag parser (replaces `clap`)
//! * [`stats`] — streaming summary statistics for benches and reports
//! * [`prop`]  — seeded property-test driver (replaces `proptest`)

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
