//! Deterministic PRNG: splitmix64 seeding + xoshiro256++ generation.
//!
//! Replaces the `rand` crate (unavailable offline). The generator is the
//! reference xoshiro256++ by Blackman & Vigna (public domain), which is more
//! than adequate for synthetic matrix generation and property tests, and —
//! crucially for reproducibility of EXPERIMENTS.md — fully deterministic
//! across platforms for a given seed.

/// xoshiro256++ generator, seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Unbiased uniform integer in [0, bound) via Lemire's method.
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // 64-bit multiply-shift; bias negligible for bound << 2^64 and
        // irrelevant for workload generation.
        let x = self.next_u64();
        ((x as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform integer in the inclusive range [lo, hi].
    #[inline]
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.usize_below(hi - lo + 1)
    }

    /// Standard normal (Box–Muller; one value per call, simple over fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Sample from a discrete power law P(k) ~ k^-r over k in [1, kmax]
    /// by inverse-CDF on the continuous Pareto and clamping.
    ///
    /// Used to draw per-column non-zero counts matching the paper's
    /// Table-2 exponents (P(k) ~ k^-R, R in [1, 4]).
    pub fn power_law(&mut self, r: f64, kmax: usize) -> usize {
        debug_assert!(r > 0.0 && kmax >= 1);
        let u = self.f64();
        let k = if (r - 1.0).abs() < 1e-9 {
            // r == 1: CDF is log-uniform
            (kmax as f64).powf(u)
        } else {
            // inverse CDF of Pareto truncated to [1, kmax]
            let a = 1.0 - r;
            let km = (kmax as f64).powf(a);
            (1.0 + u * (km - 1.0)).powf(1.0 / a)
        };
        (k.floor() as usize).clamp(1, kmax)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_below_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.usize_below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn power_law_bounds_and_skew() {
        let mut r = Rng::new(8);
        let kmax = 1000;
        let xs: Vec<usize> = (0..50_000).map(|_| r.power_law(2.0, kmax)).collect();
        assert!(xs.iter().all(|&k| (1..=kmax).contains(&k)));
        // heavy skew: k=1 must be by far the most common outcome
        let ones = xs.iter().filter(|&&k| k == 1).count();
        assert!(ones > xs.len() / 3, "ones={ones}");
        // but the tail must exist
        assert!(xs.iter().any(|&k| k > 50));
    }

    #[test]
    fn power_law_r1_log_uniform() {
        let mut r = Rng::new(9);
        let xs: Vec<usize> = (0..50_000).map(|_| r.power_law(1.0, 1024)).collect();
        assert!(xs.iter().all(|&k| (1..=1024).contains(&k)));
        // log-uniform: ~10% of mass per decade factor; median ~ sqrt(kmax)=32
        let mut s = xs.clone();
        s.sort_unstable();
        let median = s[s.len() / 2];
        assert!((8..=128).contains(&median), "median={median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(11);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
