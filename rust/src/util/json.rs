//! Minimal JSON reader/writer (replaces `serde_json`, unavailable offline).
//!
//! Scope: exactly what the artifact manifest needs — objects, arrays,
//! strings, numbers, booleans, null, UTF-8 input, `\uXXXX` escapes. Not a
//! general-purpose library, but a complete parser for valid JSON with
//! precise byte-offset error reporting.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (stored as f64; manifest integers are < 2^53)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Value>),
    /// object (BTreeMap keeps output deterministic)
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// As object map, or None.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// As array slice, or None.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As string, or None.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64, or None.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (must be a non-negative integral number), or None.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (entire input must be consumed).
pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            at: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 already advanced
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let s_rest = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s_rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Value::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀");
    }

    #[test]
    fn parse_whitespace_tolerant() {
        let v = parse(" \n\t{ \"k\" : [ ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn reject_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn error_reports_offset() {
        match parse("[1, @]") {
            Err(Error::Json { at, .. }) => assert_eq!(at, 4),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-7,"o":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_json()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(parse("2.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
    }
}
