//! Seeded property-test driver (replaces `proptest`, unavailable offline).
//!
//! A property is a closure over a [`Gen`]; the driver runs it for a fixed
//! number of deterministic cases. On failure it reports the case seed so the
//! exact input can be replayed by setting `MSREP_PROP_SEED`. No shrinking —
//! generators are written to produce small cases early (sizes ramp up with
//! the case index), which in practice localises failures well enough.
//!
//! ```
//! use msrep::util::prop::{check, Gen};
//! check("reverse twice is identity", 64, |g| {
//!     let xs = g.vec_usize(0..g.size().max(1), 100);
//!     let mut twice = xs.clone();
//!     twice.reverse();
//!     twice.reverse();
//!     assert_eq!(xs, twice);
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator handle: a PRNG plus a size hint that grows with the
/// case index (case 0 is smallest), so early failures are small failures.
pub struct Gen {
    rng: Rng,
    size: usize,
}

impl Gen {
    /// Current size hint (grows with case index; use to bound dimensions).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Uniform usize in [lo, hi) (half-open, like ranges).
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        self.rng.usize_range(range.start, range.end - 1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    /// Boolean with probability p of true.
    pub fn prob(&mut self, p: f64) -> bool {
        self.rng.f64() < p
    }

    /// Vector of uniform usize below `bound`, with length drawn from `len`.
    pub fn vec_usize(&mut self, len: std::ops::Range<usize>, bound: usize) -> Vec<usize> {
        let n = if len.is_empty() { len.start } else { self.usize_in(len) };
        (0..n).map(|_| self.rng.usize_below(bound.max(1))).collect()
    }

    /// Vector of uniform f32 in [-1, 1).
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.f32_range(-1.0, 1.0)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize_below(xs.len())]
    }

    /// Access the raw RNG (for domain-specific generators).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Base seed: `MSREP_PROP_SEED` env var if set, else a fixed default so CI
/// is deterministic.
pub fn base_seed() -> u64 {
    std::env::var("MSREP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `body` for `cases` deterministic cases. Panics (with the replay seed
/// in the message) if the body panics for any case.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, body: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ ((case as u64) << 32) ^ 0x9E3779B97F4A7C15u64.wrapping_mul(case as u64);
        // size ramps 4 -> ~4+cases
        let size = 4 + case;
        let mut gen = Gen { rng: Rng::new(seed), size };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut gen)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: MSREP_PROP_SEED={base}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check("always true", 10, |g| {
            let _ = g.usize_in(0..5);
            **counter.borrow_mut() += 1;
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_name_and_seed() {
        check("fails", 5, |g| {
            assert!(g.usize_in(0..10) > 100, "impossible");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = vec![];
        let mut second: Vec<usize> = vec![];
        {
            let sink = std::cell::RefCell::new(&mut first);
            check("collect1", 8, |g| sink.borrow_mut().push(g.usize_in(0..1000)));
        }
        {
            let sink = std::cell::RefCell::new(&mut second);
            check("collect2", 8, |g| sink.borrow_mut().push(g.usize_in(0..1000)));
        }
        assert_eq!(first, second);
    }

    #[test]
    fn size_ramps_up() {
        let mut sizes = vec![];
        let sink = std::cell::RefCell::new(&mut sizes);
        check("sizes", 6, |g| sink.borrow_mut().push(g.size()));
        assert_eq!(sizes, vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn vec_generators_respect_bounds() {
        check("vec bounds", 20, |g| {
            let v = g.vec_usize(0..10, 7);
            assert!(v.len() < 10);
            assert!(v.iter().all(|&x| x < 7));
            let f = g.vec_f32(5);
            assert!(f.iter().all(|&x| (-1.0..1.0).contains(&x)));
        });
    }
}
