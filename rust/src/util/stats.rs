//! Summary statistics for benches and reports (replaces criterion's stats).

use std::time::Duration;

/// Summary of a sample set (times in seconds or any positive metric).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// number of samples
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// sample standard deviation (n-1); 0 for n < 2
    pub std_dev: f64,
    /// minimum
    pub min: f64,
    /// maximum
    pub max: f64,
    /// median (p50)
    pub median: f64,
    /// 95th percentile
    pub p95: f64,
}

impl Summary {
    /// Compute a summary of the samples. Non-finite samples (NaN, ±inf)
    /// are dropped before aggregation — a single poisoned timing must not
    /// corrupt the sort order or the moments — and `n` counts the finite
    /// samples actually summarized. Panics (with a clear message) when the
    /// input is empty or no sample is finite.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty samples");
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        assert!(!sorted.is_empty(), "Summary::of: no finite samples");
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
        }
    }

    /// Summary of durations, in seconds.
    pub fn of_durations(ds: &[Duration]) -> Summary {
        let secs: Vec<f64> = ds.iter().map(|d| d.as_secs_f64()).collect();
        Summary::of(&secs)
    }

    /// Relative std dev (coefficient of variation).
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0, 1].
///
/// The input **must** be sorted ascending — unsorted input silently
/// returns garbage, so debug builds assert the invariant instead of
/// trusting the caller's documentation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    debug_assert!(
        sorted.windows(2).all(|w| w[0] <= w[1]),
        "percentile requires sorted input"
    );
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Robust location/scale summary: median + MAD (median absolute
/// deviation). The perf observatory reduces measured wall-clock samples
/// with this instead of mean/σ because a single scheduler hiccup would
/// drag a mean arbitrarily far while leaving the median untouched
/// (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Robust {
    /// number of finite samples summarized
    pub n: usize,
    /// sample median
    pub median: f64,
    /// median absolute deviation from the median (un-scaled)
    pub mad: f64,
}

impl Robust {
    /// Compute median + MAD of the samples. Non-finite samples are
    /// dropped like [`Summary::of`]; panics when the input is empty or no
    /// sample is finite.
    pub fn of(samples: &[f64]) -> Robust {
        assert!(!samples.is_empty(), "Robust::of on empty samples");
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        assert!(!sorted.is_empty(), "Robust::of: no finite samples");
        sorted.sort_by(f64::total_cmp);
        let median = percentile(&sorted, 0.50);
        let mut dev: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        dev.sort_by(f64::total_cmp);
        Robust { n: sorted.len(), median, mad: percentile(&dev, 0.50) }
    }

    /// σ-equivalent scale: MAD × 1.4826 (the consistency constant that
    /// makes the MAD estimate σ for normally distributed noise).
    pub fn sigma(&self) -> f64 {
        self.mad * 1.4826
    }
}

/// Median absolute deviation of a sample set (convenience over
/// [`Robust::of`]).
pub fn mad(samples: &[f64]) -> f64 {
    Robust::of(samples).mad
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Imbalance factor of a workload distribution: max/mean. 1.0 == perfectly
/// balanced. This is the quantity MSREP's nnz-balanced partitioning drives
/// to 1 (paper §2.3).
pub fn imbalance(loads: &[u64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let max = *loads.iter().max().unwrap() as f64;
    let mean = loads.iter().sum::<u64>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.median, 2.0);
        assert_eq!((s.min, s.max), (2.0, 2.0));
    }

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.median, 2.5);
        assert!((s.std_dev - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 0.5), 5.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.p95, 5.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_balanced_is_one() {
        assert_eq!(imbalance(&[5, 5, 5, 5]), 1.0);
    }

    #[test]
    fn imbalance_skewed() {
        // one GPU with 10x the load of the others (paper Fig. 6 scenario)
        let im = imbalance(&[10, 1, 1, 1]);
        assert!((im - 10.0 / 3.25).abs() < 1e-12);
    }

    #[test]
    fn imbalance_degenerate() {
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0, 0]), 1.0);
    }

    #[test]
    fn robust_median_and_mad_known_values() {
        // median 3, |x - 3| = [2, 1, 0, 1, 2] -> MAD 1
        let r = Robust::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(r.n, 5);
        assert_eq!(r.median, 3.0);
        assert_eq!(r.mad, 1.0);
        assert!((r.sigma() - 1.4826).abs() < 1e-12);
        assert_eq!(mad(&[1.0, 2.0, 3.0, 4.0, 5.0]), 1.0);
    }

    #[test]
    fn robust_shrugs_off_a_wild_outlier() {
        // one poisoned 100x timing: mean moves ~20x, median stays put
        let clean = Robust::of(&[1.0, 1.1, 0.9, 1.0, 1.05]);
        let spiked = Robust::of(&[1.0, 1.1, 0.9, 100.0, 1.05]);
        assert_eq!(clean.median, 1.0);
        assert_eq!(spiked.median, 1.05);
        assert!(spiked.mad < 0.2, "MAD must stay noise-sized, got {}", spiked.mad);
    }

    #[test]
    fn robust_constant_samples_have_zero_mad() {
        let r = Robust::of(&[2.5, 2.5, 2.5]);
        assert_eq!(r.median, 2.5);
        assert_eq!(r.mad, 0.0);
        assert_eq!(r.sigma(), 0.0);
    }

    #[test]
    fn robust_drops_non_finite_samples() {
        let r = Robust::of(&[2.0, f64::NAN, 4.0, f64::INFINITY]);
        assert_eq!(r.n, 2);
        assert_eq!(r.median, 3.0);
    }

    #[test]
    #[should_panic(expected = "empty samples")]
    fn robust_of_empty_panics_cleanly() {
        Robust::of(&[]);
    }

    #[test]
    fn of_durations_converts() {
        let s = Summary::of_durations(&[Duration::from_millis(100), Duration::from_millis(300)]);
        assert!((s.mean - 0.2).abs() < 1e-9);
    }

    #[test]
    fn summary_drops_nan_and_infinite_samples() {
        let s = Summary::of(&[2.0, f64::NAN, 4.0, f64::INFINITY, f64::NEG_INFINITY]);
        assert_eq!(s.n, 2);
        assert_eq!(s.mean, 3.0);
        assert_eq!((s.min, s.max), (2.0, 4.0));
    }

    #[test]
    #[should_panic(expected = "no finite samples")]
    fn summary_of_all_nan_panics_cleanly() {
        Summary::of(&[f64::NAN, f64::NAN]);
    }

    #[test]
    #[should_panic(expected = "empty samples")]
    fn summary_of_empty_panics_cleanly() {
        Summary::of(&[]);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "sorted input")]
    fn percentile_rejects_unsorted_in_debug() {
        percentile(&[3.0, 1.0, 2.0], 0.5);
    }

    #[test]
    fn percentile_single_sample_is_that_sample() {
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 0.33), 7.5);
        assert_eq!(percentile(&[7.5], 1.0), 7.5);
    }
}
