//! Profile-driven format auto-tuning: pick `(format, strategy, np)` per
//! matrix instead of hardcoding it in [`RunConfig`].
//!
//! MSREP's premise is that pCSR/pCSC/pCOO each win on different sparsity
//! structures (paper §2.1, §5.5) — yet every caller used to pin one format
//! for the whole run. Structure-driven selection is the standard answer
//! (Yang et al. pick the format per matrix structure; Kreutzer et al.
//! choose storage by the row-length distribution), and this module is its
//! MSREP instantiation:
//!
//! 1. **profile** — [`stats::profile`] extracts cheap structural features
//!    (density, row/col CV, bandwidth, power-law R) in one O(nnz) pass;
//! 2. **enumerate** — every `(format, strategy, np)` combination of an
//!    [`AutoPlanOptions`] candidate set is materialized as a real
//!    [`PartitionPlan`] (candidates that cannot build, e.g. block
//!    partitioning of col-sorted COO, are skipped);
//! 3. **price** — each candidate is charged by the *same* cost model the
//!    engine executes under:
//!    [`model_spmv_phases`](crate::coordinator::model_spmv_phases) for the
//!    replay cost, the plan's own `t_partition` for the build, amortized
//!    over [`AutoPlanOptions::reuse`] expected SpMVs;
//! 4. **rank** — candidates sort by amortized cost with a deterministic
//!    structural tie-break, and the winner's plan ships in the returned
//!    [`AutoPlan`] together with the full rationale table
//!    ([`crate::report::render_autoplan_report`] renders it).
//!
//! Because step 3 reuses the engine's own pricing function, the tuner's
//! predicted cost of a candidate **is** the `modeled_total` that
//! [`Engine::spmv_with_plan`](crate::coordinator::Engine::spmv_with_plan)
//! reports when the plan is replayed — the `plan_auto`-equals-brute-force
//! property test in `tests/autoplan_integration.rs` holds by construction
//! and guards the shared core against drift.
//!
//! Entry points: [`Engine::plan_auto`](crate::coordinator::Engine::plan_auto)
//! (candidates restricted to plans executable on that engine),
//! [`plan_auto`] with [`AutoPlanOptions::full_sweep`] (the full
//! `(format, strategy, np)` grid), serve-side per-tenant routing via
//! [`Server::register_auto`](crate::serve::Server::register_auto), and the
//! `PlanSource::Auto` arm of [`crate::solver::SolverConfig`]. See
//! DESIGN.md §12.

use crate::coordinator::{model_spmv_phases, Engine, PartitionPlan, RunConfig, SpmvPhases, Strategy};
use crate::error::{Error, Result};
use crate::formats::stats::{self, Profile};
use crate::formats::{convert, FormatKind, Matrix};
use crate::sim::model;

/// One point of the tuner's search space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// storage format the matrix would be converted into
    pub format: FormatKind,
    /// partitioning strategy
    pub strategy: Strategy,
    /// GPU count
    pub np: usize,
}

impl Candidate {
    /// `csr/balanced/np8`-style label for reports.
    pub fn label(&self) -> String {
        format!("{}/{}/np{}", self.format.name(), self.strategy.label(), self.np)
    }
}

/// A candidate with its modeled price tag.
#[derive(Debug, Clone)]
pub struct CandidateCost {
    /// the configuration point
    pub candidate: Candidate,
    /// modeled one-off partitioning cost of building the plan (§4.1)
    pub t_partition: f64,
    /// modeled per-SpMV replay phases (h2d / compute / merge)
    pub phases: SpmvPhases,
    /// per-GPU work imbalance of the candidate plan (max/mean)
    pub imbalance: f64,
}

impl CandidateCost {
    /// Modeled cost of one SpMV replay (`phases.total()`).
    pub fn spmv_s(&self) -> f64 {
        self.phases.total()
    }

    /// The ranking objective: one SpMV replay plus the build cost
    /// amortized over `reuse` expected replays.
    pub fn amortized_s(&self, reuse: usize) -> f64 {
        self.spmv_s() + self.t_partition / reuse.max(1) as f64
    }
}

/// The tuner's candidate set and amortization horizon.
#[derive(Debug, Clone)]
pub struct AutoPlanOptions {
    /// storage formats to enumerate
    pub formats: Vec<FormatKind>,
    /// partitioning strategies to enumerate
    pub strategies: Vec<Strategy>,
    /// GPU counts to enumerate (each `>= 1` and `<=` the platform's GPUs)
    pub np_choices: Vec<usize>,
    /// expected SpMV replays per plan build — the amortization horizon the
    /// build cost is spread over (1 = the paper's one-shot call shape,
    /// larger = serving / iterative-solver traffic). Default 32.
    pub reuse: usize,
}

impl AutoPlanOptions {
    /// Candidates executable on an engine running `cfg`: formats free
    /// (the engine follows the plan's format), strategy and GPU count
    /// pinned to the engine's — the restriction
    /// [`Engine::plan_auto`](crate::coordinator::Engine::plan_auto) and
    /// the serving layer use so the winning plan replays without
    /// reconfiguring anything.
    pub fn for_config(cfg: &RunConfig) -> AutoPlanOptions {
        AutoPlanOptions {
            formats: FormatKind::ALL.to_vec(),
            strategies: vec![cfg.effective_strategy()],
            np_choices: vec![cfg.num_gpus],
            reuse: 32,
        }
    }

    /// The full `(format, strategy, np)` grid under `cfg`'s platform:
    /// every registered format, both strategies, and power-of-two GPU counts up
    /// to `cfg.num_gpus` (plus `cfg.num_gpus` itself). The winner of this
    /// sweep may need a reconfigured engine — [`AutoPlan::config`] is the
    /// ready-made [`RunConfig`] for it.
    pub fn full_sweep(cfg: &RunConfig) -> AutoPlanOptions {
        let mut np_choices = Vec::new();
        let mut np = 1usize;
        while np < cfg.num_gpus {
            np_choices.push(np);
            np *= 2;
        }
        np_choices.push(cfg.num_gpus);
        AutoPlanOptions {
            formats: FormatKind::ALL.to_vec(),
            strategies: vec![Strategy::NnzBalanced, Strategy::Blocks],
            np_choices,
            reuse: 32,
        }
    }

    /// Replace the amortization horizon (builder-style).
    pub fn with_reuse(mut self, reuse: usize) -> AutoPlanOptions {
        self.reuse = reuse;
        self
    }

    fn validate(&self, cfg: &RunConfig) -> Result<()> {
        if self.formats.is_empty() || self.strategies.is_empty() || self.np_choices.is_empty() {
            return Err(Error::Autoplan("empty candidate axis".into()));
        }
        if self.reuse == 0 {
            return Err(Error::Autoplan("reuse horizon must be >= 1".into()));
        }
        for &np in &self.np_choices {
            if np == 0 || np > cfg.platform.num_gpus {
                return Err(Error::Autoplan(format!(
                    "np {np} out of range for {} ({} GPUs)",
                    cfg.platform.name, cfg.platform.num_gpus
                )));
            }
        }
        Ok(())
    }
}

/// The tuner's verdict: the winning plan plus the full ranked rationale.
#[derive(Debug)]
pub struct AutoPlan {
    /// structural features the selection was derived from
    pub profile: Profile,
    /// every buildable candidate with its price, best (rank 0) first
    pub ranked: Vec<CandidateCost>,
    /// the winning candidate's ready-to-replay plan
    pub plan: PartitionPlan,
    /// the base configuration specialized to the winner (format, GPU
    /// count, strategy override) — build an
    /// [`Engine`](crate::coordinator::Engine) from it to execute the plan
    /// when the winner differs from the base engine's shape
    pub config: RunConfig,
    /// amortization horizon the ranking used
    pub reuse: usize,
    /// modeled cost of the tuner's *search*: the profiling pass (two
    /// streaming O(nnz) degree counts) plus the losing candidates' plan
    /// builds — everything the selection did except the winner's own
    /// build, which is charged as the plan's `t_partition`. Charged by
    /// `PlanSource::Auto` solves so the tuner is never modeled as free.
    pub t_tune: f64,
}

impl AutoPlan {
    /// The winning candidate's price line.
    pub fn choice(&self) -> &CandidateCost {
        &self.ranked[0]
    }

    /// The second-best candidate, if more than one candidate built.
    pub fn runner_up(&self) -> Option<&CandidateCost> {
        self.ranked.get(1)
    }

    /// Modeled amortized speedup of the winner over the worst candidate
    /// (>= 1; how much picking formats blindly could cost).
    pub fn worst_case_gain(&self) -> f64 {
        let worst = self.ranked.last().expect("ranked is non-empty");
        let best = self.choice().amortized_s(self.reuse);
        if best <= 0.0 {
            1.0
        } else {
            worst.amortized_s(self.reuse) / best
        }
    }
}

/// Deterministic tie-break rank so equal-cost candidates sort stably
/// (registry order CSR < CSC < COO < pSELL, balanced before blocks,
/// small np first).
fn structural_rank(c: &Candidate) -> (usize, usize, usize) {
    let s = match c.strategy {
        Strategy::NnzBalanced => 0,
        Strategy::Blocks => 1,
    };
    (c.format.spec().ordinal, s, c.np)
}

/// Run the tuner: profile `a`, build + price every candidate of `opts`
/// under `cfg`'s platform/mode, and return the ranked [`AutoPlan`].
///
/// `cfg.format`, `cfg.num_gpus` and `cfg.strategy_override` act only as
/// the *base* the candidates specialize; `cfg.platform`, `cfg.mode` and
/// `cfg.numa_aware` are shared by every candidate. Candidates that cannot
/// build are skipped; an empty surviving set is an error.
pub fn plan_auto(cfg: &RunConfig, a: &Matrix, opts: &AutoPlanOptions) -> Result<AutoPlan> {
    opts.validate(cfg)?;
    let profile = match a {
        // COO inputs (the CLI and scenario paths) profile in place
        Matrix::Coo(c) => stats::profile(c),
        _ => stats::profile(&convert::to_coo(a)),
    };
    // the profile pass: two streaming degree counts over the nnz stream
    // (row + column), priced like any other CPU sweep; the losing
    // candidates' builds join it below so the search is charged honestly
    let t_profile = model::cpu_rewrite_time(&cfg.platform, 2 * a.nnz() as u64);
    let mut builds_total = 0.0f64;

    // only the running winner's plan is kept alive — every candidate plan
    // embeds a full copy of the matrix streams, so holding all of a
    // full_sweep's plans until the end would peak at ~#candidates copies
    // of the payload for no benefit (the ranking only needs the costs)
    let mut ranked: Vec<CandidateCost> = Vec::new();
    let mut winner: Option<(f64, (usize, usize, usize), PartitionPlan, RunConfig)> = None;
    for &format in &opts.formats {
        // a candidate in the input's own format borrows it — only the
        // other formats pay a conversion copy
        let converted;
        let mat: &Matrix = if format == a.kind() {
            a
        } else {
            converted = convert::to_format(a, format);
            &converted
        };
        for &strategy in &opts.strategies {
            for &np in &opts.np_choices {
                let ccfg = RunConfig {
                    format,
                    num_gpus: np,
                    strategy_override: Some(strategy),
                    ..cfg.clone()
                };
                // infeasible combinations (e.g. block partitioning of
                // col-sorted COO) are skipped, not fatal
                let Ok(plan) = PartitionPlan::build(mat, &ccfg) else {
                    continue;
                };
                let phases = model_spmv_phases(&ccfg, &plan);
                let cost = CandidateCost {
                    candidate: Candidate { format, strategy, np },
                    t_partition: plan.t_partition,
                    phases,
                    imbalance: plan.work_imbalance(),
                };
                builds_total += plan.t_partition;
                let amortized = cost.amortized_s(opts.reuse);
                let rank_key = structural_rank(&cost.candidate);
                // same (cost, structural) order as the ranking sort below,
                // so the kept plan is exactly ranked[0]'s
                let better = winner.as_ref().map_or(true, |&(best_s, best_rank, _, _)| {
                    amortized < best_s || (amortized == best_s && rank_key < best_rank)
                });
                if better {
                    winner = Some((amortized, rank_key, plan, ccfg));
                }
                ranked.push(cost);
            }
        }
    }
    let Some((_, _, plan, config)) = winner else {
        return Err(Error::Autoplan(format!(
            "no candidate could build for a {}x{} {} matrix",
            a.rows(),
            a.cols(),
            a.kind().name()
        )));
    };
    ranked.sort_by(|x, y| {
        x.amortized_s(opts.reuse)
            .partial_cmp(&y.amortized_s(opts.reuse))
            .expect("modeled costs are finite")
            .then_with(|| structural_rank(&x.candidate).cmp(&structural_rank(&y.candidate)))
    });
    // search cost = profiling + every build except the winner's (that one
    // is the plan's own t_partition, charged by whoever replays the plan)
    let t_tune = t_profile + (builds_total - plan.t_partition).max(0.0);
    Ok(AutoPlan { profile, ranked, plan, config, reuse: opts.reuse, t_tune })
}

/// Comparison of the tuner's pick against every fixed format, priced by
/// the engine's own pricing core and amortized over the tuner's reuse
/// horizon — the acceptance surface shared by `msrep autoplan-bench` and
/// `benches/autoplan_selection.rs`, so the two CI gates cannot drift
/// apart.
#[derive(Debug, Clone)]
pub struct FixedFormatComparison {
    /// the tuner's winner: modeled replay + build cost over the horizon
    pub auto_s: f64,
    /// every fixed format's amortized total, in [`FormatKind::ALL`] order
    pub per_format: Vec<(FormatKind, f64)>,
}

impl FixedFormatComparison {
    fn sorted(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.per_format.iter().map(|&(_, t)| t).collect();
        v.sort_by(|a, b| a.partial_cmp(b).expect("modeled totals are finite"));
        v
    }

    /// Cheapest fixed format's amortized total.
    pub fn best(&self) -> f64 {
        self.sorted()[0]
    }

    /// Median fixed format's amortized total.
    pub fn median(&self) -> f64 {
        let s = self.sorted();
        s[s.len() / 2]
    }

    /// Most expensive fixed format's amortized total.
    pub fn worst(&self) -> f64 {
        *self.sorted().last().expect("at least one format")
    }

    /// Modeled speedup of the tuner over the median fixed format.
    pub fn vs_median(&self) -> f64 {
        self.median() / self.auto_s
    }

    /// Acceptance gate 1: never worse than the worst fixed format.
    pub fn never_worse_than_worst(&self) -> bool {
        self.auto_s <= self.worst() * (1.0 + 1e-9)
    }

    /// Acceptance gate 2: the tuner's pick *is* the best fixed format —
    /// with the shared pricing core the argmin cannot be missed.
    pub fn matches_best(&self) -> bool {
        self.auto_s <= self.best() * (1.0 + 1e-9)
    }
}

/// Build the fixed-format comparison for `auto` on `engine`: every fixed
/// format's amortized total at the engine's GPU count and strategy,
/// priced by the same shared core as the tuner itself. Formats the tuner
/// already ranked (a [`AutoPlanOptions::for_config`] run covers all
/// three) are read straight from `auto.ranked` — no rebuild; formats the
/// tuner's candidate set skipped (restricted sets, `full_sweep` results
/// for a different engine shape) are built and priced on the spot.
pub fn compare_fixed_formats(
    engine: &Engine,
    a: &Matrix,
    auto: &AutoPlan,
) -> Result<FixedFormatComparison> {
    let reuse = auto.reuse.max(1);
    let np = engine.config().num_gpus;
    let strategy = engine.config().effective_strategy();
    let mut per_format = Vec::with_capacity(FormatKind::ALL.len());
    for &format in &FormatKind::ALL {
        let ranked_row = auto.ranked.iter().find(|r| {
            r.candidate.format == format
                && r.candidate.np == np
                && r.candidate.strategy == strategy
        });
        let total = match ranked_row {
            // the tuner already built and priced this exact candidate
            Some(r) => r.amortized_s(reuse),
            None => {
                let mat = convert::to_format(a, format);
                let ccfg = RunConfig {
                    format,
                    num_gpus: np,
                    strategy_override: Some(strategy),
                    ..engine.config().clone()
                };
                // unbuildable formats are skipped, matching plan_auto's
                // skip-not-fatal candidate semantics — the comparison
                // ranks whatever does build
                let Ok(plan) = PartitionPlan::build(&mat, &ccfg) else {
                    continue;
                };
                model_spmv_phases(&ccfg, &plan).total() + plan.t_partition / reuse as f64
            }
        };
        per_format.push((format, total));
    }
    if per_format.is_empty() {
        return Err(Error::Autoplan("no fixed format could build for the comparison".into()));
    }
    let auto_s = auto.choice().amortized_s(reuse);
    Ok(FixedFormatComparison { auto_s, per_format })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Mode};
    use crate::formats::gen;
    use crate::sim::Platform;

    fn cfg(np: usize) -> RunConfig {
        RunConfig {
            platform: Platform::dgx1(),
            num_gpus: np,
            mode: Mode::PStarOpt,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: None,
        }
    }

    #[test]
    fn options_validation() {
        let c = cfg(8);
        let base = AutoPlanOptions::for_config(&c);
        let a = Matrix::Coo(gen::uniform(50, 50, 400, 1));
        assert!(plan_auto(&c, &a, &base).is_ok());
        let empty = AutoPlanOptions { formats: vec![], ..base.clone() };
        assert!(plan_auto(&c, &a, &empty).is_err());
        let zero_reuse = AutoPlanOptions { reuse: 0, ..base.clone() };
        assert!(plan_auto(&c, &a, &zero_reuse).is_err());
        let bad_np = AutoPlanOptions { np_choices: vec![9], ..base };
        assert!(plan_auto(&c, &a, &bad_np).is_err());
    }

    #[test]
    fn ranked_is_sorted_and_covers_all_formats() {
        let c = cfg(8);
        let a = Matrix::Coo(gen::power_law(800, 800, 15_000, 2.0, 2));
        let auto = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c)).unwrap();
        assert_eq!(auto.ranked.len(), 4, "one candidate per format");
        for w in auto.ranked.windows(2) {
            assert!(
                w[0].amortized_s(auto.reuse) <= w[1].amortized_s(auto.reuse) + 1e-18,
                "ranking out of order"
            );
        }
        // the winner's plan matches its own rank-0 row
        assert_eq!(auto.plan.format, auto.choice().candidate.format);
        assert_eq!(auto.plan.np, 8);
        assert!(auto.worst_case_gain() >= 1.0);
        assert!(auto.t_tune > 0.0);
        // the specialized config really is executable
        crate::coordinator::Engine::new(auto.config.clone()).unwrap();
    }

    #[test]
    fn full_sweep_enumerates_np_and_strategies() {
        let c = cfg(8);
        let a = Matrix::Coo(gen::uniform(400, 400, 6_000, 3));
        let auto = plan_auto(&c, &a, &AutoPlanOptions::full_sweep(&c)).unwrap();
        // 4 formats x 2 strategies x np {1,2,4,8}, minus unbuildable
        // combinations — at least the balanced grid must survive
        assert!(auto.ranked.len() >= 16, "only {} candidates", auto.ranked.len());
        let nps: std::collections::BTreeSet<usize> =
            auto.ranked.iter().map(|r| r.candidate.np).collect();
        assert!(nps.contains(&1) && nps.contains(&8));
        assert!(auto
            .ranked
            .iter()
            .any(|r| r.candidate.strategy == Strategy::Blocks));
    }

    #[test]
    fn wide_matrix_routes_to_csc_tall_to_csr() {
        let c = cfg(8);
        let wide = Matrix::Coo(gen::power_law(512, 20_000, 150_000, 2.0, 4));
        let auto = plan_auto(&c, &wide, &AutoPlanOptions::for_config(&c)).unwrap();
        assert_eq!(auto.choice().candidate.format, FormatKind::Csc, "wide input");
        let tall = Matrix::Coo(gen::power_law(20_000, 512, 150_000, 2.0, 5));
        let auto = plan_auto(&c, &tall, &AutoPlanOptions::for_config(&c)).unwrap();
        assert_eq!(auto.choice().candidate.format, FormatKind::Csr, "tall input");
    }

    #[test]
    fn banded_stencil_routes_to_psell_and_strictly_beats_every_legacy_format() {
        // the pSELL acceptance scenario (DESIGN.md §17): a near-uniform
        // PDE band pads almost nothing, so the 0.70-efficiency sliced
        // stream undercuts every dense-stream format's modeled replay
        // cost — the tuner must both pick it and beat each legacy
        // format's modeled max-GPU compute time strictly
        let c = cfg(8);
        let s = crate::workload::autoplan_scenario_by_name("banded-stencil").unwrap();
        let a = Matrix::Coo(crate::workload::autoplan_scenario_matrix(&s));
        let auto = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c)).unwrap();
        assert_eq!(auto.choice().candidate.format, FormatKind::PSell, "banded input");
        let psell =
            auto.ranked.iter().find(|r| r.candidate.format == FormatKind::PSell).unwrap();
        for r in &auto.ranked {
            if r.candidate.format != FormatKind::PSell {
                assert!(
                    psell.phases.t_compute < r.phases.t_compute,
                    "pSELL max-GPU compute {} must strictly beat {}'s {}",
                    psell.phases.t_compute,
                    r.candidate.format.name(),
                    r.phases.t_compute
                );
            }
        }
    }

    #[test]
    fn fixed_format_comparison_matches_ranked_costs() {
        let c = cfg(8);
        let engine = Engine::new(c.clone()).unwrap();
        let a = Matrix::Coo(gen::power_law(400, 1_200, 10_000, 2.0, 7));
        let auto = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c)).unwrap();
        let cmp = compare_fixed_formats(&engine, &a, &auto).unwrap();
        assert!(cmp.matches_best() && cmp.never_worse_than_worst());
        // the comparison's totals are the tuner's own ranked costs — one
        // pricing core, no second definition to drift
        for &(f, t) in &cmp.per_format {
            let row = auto.ranked.iter().find(|r| r.candidate.format == f).unwrap();
            assert_eq!(t, row.amortized_s(auto.reuse), "{f:?}");
        }
        assert_eq!(cmp.auto_s, auto.choice().amortized_s(auto.reuse));
        assert!(cmp.vs_median() >= 1.0);
    }

    #[test]
    fn reuse_horizon_can_flip_the_choice_toward_cheap_builds() {
        // at reuse = 1 the build cost dominates the objective; at large
        // reuse it vanishes — the two objectives must at least order
        // amortized costs differently when t_partition differs
        let c = cfg(8);
        let a = Matrix::Coo(gen::uniform(2_000, 2_000, 40_000, 6));
        let one = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c).with_reuse(1)).unwrap();
        let many =
            plan_auto(&c, &a, &AutoPlanOptions::for_config(&c).with_reuse(10_000)).unwrap();
        for r in one.ranked.iter().chain(many.ranked.iter()) {
            assert!(r.amortized_s(1) >= r.spmv_s());
        }
        // large-horizon objective converges to the bare replay cost
        let best = many.choice();
        assert!((best.amortized_s(10_000) - best.spmv_s()) < best.spmv_s() * 0.05);
    }
}
