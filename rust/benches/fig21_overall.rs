//! Bench: paper Fig. 21 — overall speedup of baseline / p\* / p\*-opt.
//!
//! Prints the regenerated speedup-vs-GPUs series (geomean over the
//! Table-2 suite, CSR) for both platforms. Expected shape: baseline flat,
//! p\* scales then sags (no NUMA awareness), p\*-opt near-linear.

use msrep::report::figures::{self, SuiteCache};
use msrep::report::Series;
use msrep::util::bench::section;

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cache = if quick { SuiteCache::build_quick(2) } else { SuiteCache::build() };

    section("Fig. 21 — overall speedup vs #GPUs (geomean over suite, CSR)");
    for (platform, series) in figures::fig21_overall(&cache).expect("fig21") {
        println!("\n--- {platform} ---");
        print!("{}", Series::render_table(&series, "gpus"));
    }
}
