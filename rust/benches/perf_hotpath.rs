//! §Perf hot-path profiling (EXPERIMENTS.md §Perf): the real host-side
//! costs of the request path, measured on the HV15R-scale analog.
//!
//! Run with `cargo bench --bench perf_hotpath`. These are *measured* wall
//! times on this container, not modeled platform times — they are what the
//! L3 optimization iterations target.

use msrep::coordinator::partitioner::{balanced, baseline};
use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::runtime::SpmvRuntime;
use msrep::sim::Platform;
use msrep::util::bench::{black_box, section, Bench};

fn main() {
    let b = Bench::from_env();
    let coo = gen::power_law(7_000, 7_000, 987_000, 3.09, 106); // HV15R analog
    let csr = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo.clone())));
    let coo_m = Matrix::Coo(coo);

    section("L3 partition build (np=8, HV15R analog ~1M nnz)");
    for (label, mat) in [("csr", &csr), ("coo", &coo_m)] {
        let r = b.run(&format!("partition/balanced/{label}"), || {
            black_box(balanced(mat, 8).unwrap())
        });
        println!("{}", r.render());
        let r = b.run(&format!("partition/blocks/{label}"), || {
            black_box(baseline(mat, 8).unwrap())
        });
        println!("{}", r.render());
    }

    section("engine end-to-end, CpuRef backend (measured host wall)");
    let x = gen::dense_vector(7_000, 7);
    let eng = Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap();
    let r = b.run("engine/cpuref/spmv_1Mnnz", || {
        black_box(eng.spmv(&csr, &x, 1.0, 0.0, None).unwrap().y[0])
    });
    println!("{}", r.render());
    let rep = eng.spmv(&csr, &x, 1.0, 0.0, None).unwrap();
    println!(
        "  breakdown: partition {:.2} ms, exec {:.2} ms, merge {:.2} ms",
        rep.metrics.measured_partition * 1e3,
        rep.metrics.measured_exec * 1e3,
        rep.metrics.measured_merge * 1e3
    );

    section("PJRT runtime (measured host wall; artifacts required)");
    match SpmvRuntime::with_default_artifacts() {
        Err(e) => println!("  skipped: {e}"),
        Ok(rt) => {
            // one partition-sized call (1M/8 nnz -> 262144 bucket)
            let nnz = 987_000 / 8;
            let val = vec![1.0f32; nnz];
            let col: Vec<u32> = (0..nnz).map(|i| (i % 7_000) as u32).collect();
            let row: Vec<u32> = (0..nnz).map(|i| (i % 875) as u32).collect();
            let xs = vec![1.0f32; 7_000];
            // warm the executable cache first
            rt.spmv_partial(&val, &col, &row, &xs, 1.0, 875).unwrap();
            let r = b.run("runtime/spmv_partial/123k_nnz", || {
                black_box(rt.spmv_partial(&val, &col, &row, &xs, 1.0, 875).unwrap()[0])
            });
            println!("{}", r.render());

            // isolate the padding + literal-construction cost
            let r = b.run("runtime/pad_and_literal_only/123k_nnz", || {
                let mut buf = vec![0.0f32; 262_144];
                buf[..nnz].copy_from_slice(&val);
                let l = xla::Literal::vec1(&buf);
                let mut ibuf = vec![0i32; 262_144];
                for (bb, &c) in ibuf.iter_mut().zip(&col) {
                    *bb = c as i32;
                }
                let l2 = xla::Literal::vec1(&ibuf);
                black_box((l, l2))
            });
            println!("{}", r.render());

            let eng = Engine::with_runtime(
                RunConfig {
                    platform: Platform::dgx1(),
                    num_gpus: 8,
                    mode: Mode::PStarOpt,
                    format: FormatKind::Csr,
                    backend: Backend::Pjrt,
                    numa_aware: None,
                    strategy_override: None,
                },
                Some(rt),
            )
            .unwrap();
            let r = b.run("engine/pjrt/spmv_1Mnnz", || {
                black_box(eng.spmv(&csr, &x, 1.0, 0.0, None).unwrap().y[0])
            });
            println!("{}", r.render());
            if let Some(s) = eng.runtime_stats() {
                println!(
                    "  runtime stats: {} spmv calls, padding waste {:.2}x",
                    s.spmv_calls,
                    s.padding_waste()
                );
            }
        }
    }
}
