//! Bench: paper Fig. 23 (+ the DGX-1 companion figure) — per-matrix
//! p\*-opt speedup across the full suite.
//!
//! The paper's headline claims live here: 5.5× @ 6 GPUs (Summit) and
//! 6.2× @ 8 GPUs (DGX-1).

use msrep::report::figures::{self, SuiteCache};
use msrep::report::Series;
use msrep::util::bench::section;
use msrep::util::stats::geomean;

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cache = if quick { SuiteCache::build_quick(2) } else { SuiteCache::build() };

    section("Fig. 23 — per-matrix p*-opt speedup vs #GPUs (CSR)");
    for (platform, series) in figures::fig23_per_matrix(&cache).expect("fig23") {
        println!("\n--- {platform} ---");
        print!("{}", Series::render_table(&series, "gpus"));
        let finals: Vec<f64> = series.iter().map(|s| s.points.last().unwrap().1).collect();
        println!(
            "geomean final speedup: {:.2}x @ {:.0} GPUs (paper: 5.5x summit / 6.2x dgx1)",
            geomean(&finals),
            series[0].points.last().unwrap().0
        );
    }
}
