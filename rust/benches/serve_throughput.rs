//! Bench: serving-layer throughput — batched SpMM dispatch vs sequential
//! per-request SpMV (the ISSUE-1 acceptance experiment).
//!
//! A closed burst of requests against one matrix is served at increasing
//! max batch sizes; throughput is completed requests per **modeled**
//! second. The sequential reference (batch 1, no plan cache) re-partitions
//! on every call like the paper's one-shot engine; the batched server
//! amortizes the partition plan via the cache and the sparse stream via
//! SpMM coalescing. Expected: >= 2x modeled throughput at batch >= 8 on
//! the DGX-1 preset, with a plan-cache hit rate > 0.
//!
//! Run with `cargo bench --bench serve_throughput`
//! (`MSREP_BENCH_QUICK=1` shrinks the host-wall measurement).

use msrep::coordinator::{Backend, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::report::Table;
use msrep::serve::{ServeConfig, ServeReport, Server, SpmvRequest};
use msrep::sim::Platform;
use msrep::util::bench::{black_box, section, Bench};

const M: usize = 4_096;
const NNZ: usize = 200_000;
const REQUESTS: usize = 128;

fn base_config(max_batch: usize, cache: usize) -> ServeConfig {
    ServeConfig {
        run: RunConfig {
            platform: Platform::dgx1(),
            num_gpus: 8,
            mode: Mode::PStarOpt,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: None,
        },
        num_engines: 1,
        max_batch,
        flush_deadline_s: 50e-6,
        queue_capacity: REQUESTS,
        plan_cache_capacity: cache,
        cluster: None,
    }
}

fn run_once(max_batch: usize, cache: usize) -> ServeReport {
    let mut server = Server::new(base_config(max_batch, cache)).expect("server");
    let coo = gen::power_law(M, M, NNZ, 2.0, 54);
    let id = server.register(Matrix::Csr(convert::to_csr(&Matrix::Coo(coo))));
    let trace: Vec<SpmvRequest> = (0..REQUESTS)
        .map(|i| SpmvRequest {
            matrix: id,
            x: gen::dense_vector(M, 500 + i as u64),
            alpha: 1.0,
            arrival_s: 0.0,
            deadline_s: None,
        })
        .collect();
    server.run(trace).expect("serve run")
}

fn main() {
    section("serve throughput — batched SpMM vs sequential per-request SpMV (DGX-1 x8)");
    println!(
        "one tenant, {M} x {M} power-law matrix (~{NNZ} nnz), {REQUESTS}-request burst\n"
    );

    let sequential = run_once(1, 0);
    let seq_rps = sequential.throughput_rps();

    let mut t = Table::new([
        "max batch",
        "mean k",
        "modeled req/s",
        "speedup vs sequential",
        "p50 latency",
        "p99 latency",
        "cache hit rate",
    ]);
    t.row([
        "1 (no cache)".to_string(),
        format!("{:.2}", sequential.mean_batch()),
        format!("{seq_rps:.0}"),
        "1.00x".to_string(),
        msrep::report::format_duration_s(sequential.p50()),
        msrep::report::format_duration_s(sequential.p99()),
        "0.0%".to_string(),
    ]);

    let mut speedup_at_8 = 0.0;
    for batch in [2usize, 4, 8, 16] {
        let rep = run_once(batch, 8);
        assert_eq!(rep.completed, REQUESTS, "burst must fully complete");
        let speedup = rep.throughput_rps() / seq_rps;
        if batch == 8 {
            speedup_at_8 = speedup;
        }
        t.row([
            batch.to_string(),
            format!("{:.2}", rep.mean_batch()),
            format!("{:.0}", rep.throughput_rps()),
            format!("{speedup:.2}x"),
            msrep::report::format_duration_s(rep.p50()),
            msrep::report::format_duration_s(rep.p99()),
            format!("{:.1}%", rep.cache.hit_rate() * 100.0),
        ]);
    }
    print!("{}", t.render());

    let rep8 = run_once(8, 8);
    println!(
        "\nacceptance: batch-8 speedup {speedup_at_8:.2}x (target >= 2x) — {}; \
         plan-cache hit rate {:.1}% (target > 0) — {}",
        if speedup_at_8 >= 2.0 { "PASS" } else { "FAIL" },
        rep8.cache.hit_rate() * 100.0,
        if rep8.cache.hit_rate() > 0.0 { "PASS" } else { "FAIL" },
    );

    section("host-side cost of driving the serving simulation (wall time)");
    let b = Bench::from_env();
    let r = b.run("serve/run_128_requests_batch8", || {
        black_box(run_once(8, 8).completed)
    });
    println!("{}", r.render());
}
