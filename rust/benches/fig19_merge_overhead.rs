//! Bench: paper Fig. 19/22 — partial-result merging overhead (HV15R).
//!
//! Prints the regenerated merge-overhead table and micro-benchmarks the
//! real row-based and column-based merge code paths.

use msrep::coordinator::partitioner::balanced;
use msrep::coordinator::merge::merge;
use msrep::formats::{gen, FormatKind};
use msrep::report::figures::{self, SuiteCache};
use msrep::util::bench::{black_box, section, Bench};

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cache = if quick { SuiteCache::build_quick(2) } else { SuiteCache::build() };

    section("Fig. 19/22 — merge overhead (HV15R analog, % of end-to-end)");
    print!("{}", figures::fig19_merge_overhead(&cache).expect("fig19").render());

    section("real merge cost (host wall time, np=8)");
    let b = Bench::from_env();
    for format in [FormatKind::Csr, FormatKind::Csc] {
        let mat = cache.matrix("HV15R", format);
        let out = balanced(&mat, 8).unwrap();
        let x = gen::dense_vector(mat.cols(), 3);
        let partials: Vec<Vec<f32>> = out
            .tasks
            .iter()
            .map(|t| {
                let mut py = vec![0.0f32; t.out_len];
                for k in 0..t.nnz() {
                    py[t.row_idx[k] as usize] += t.val[k] * x[t.col_idx[k] as usize];
                }
                py
            })
            .collect();
        let mut y = vec![0.0f32; mat.rows()];
        let label = format.spec().merge_label;
        let r = b.run(&format!("fig19/merge/{label}/np8"), || {
            merge(&out.tasks, &partials, 0.5, &mut y).unwrap();
            black_box(y[0])
        });
        println!("{}", r.render());
    }
}
