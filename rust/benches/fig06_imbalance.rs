//! Bench: paper Fig. 6 — naive workload distribution vs nnz imbalance.
//!
//! Prints the regenerated figure (throughput vs low:high ratio on 8
//! simulated DGX-1 GPUs) and micro-benchmarks the engine run at the two
//! extremes of the sweep.

use msrep::coordinator::{Backend, Engine, Mode, RunConfig, Strategy};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::report::figures;
use msrep::sim::Platform;
use msrep::util::bench::{section, Bench};

fn main() {
    section("Fig. 6 — naive distribution vs nnz imbalance (DGX-1, 8 GPUs)");
    print!("{}", figures::fig06_imbalance().expect("fig06").render());

    section("host-side cost of one naive-distribution run (engine wall time)");
    let b = Bench::from_env();
    for ratio in [1.0f64, 10.0] {
        let coo = gen::two_band(8_192, 8_192, 800_000, ratio, 60 + ratio as u64);
        let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
        let x = gen::dense_vector(mat.cols(), 7);
        let eng = Engine::new(RunConfig {
            platform: Platform::dgx1(),
            num_gpus: 8,
            mode: Mode::PStar,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: Some(Strategy::Blocks),
        })
        .unwrap();
        let r = b.run(&format!("fig06/engine_run/ratio_1:{ratio:.0}"), || {
            eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap().metrics.modeled_total
        });
        println!("{}", r.render());
    }
}
