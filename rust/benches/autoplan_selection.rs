//! Bench: format auto-tuning on the selection scenario suite — the
//! DESIGN.md §12 acceptance sweep. For every scenario the tuner's pick is
//! compared against every fixed format through the shared acceptance
//! surface (`autoplan::compare_fixed_formats` — the same definition the
//! `msrep autoplan-bench` CI gate uses); the auto-selected plan's modeled
//! SpMV time must never be worse than the worst fixed format, must match
//! the best one (shared pricing core ⇒ the argmin cannot be missed), and
//! must strictly beat the *median* fixed format in aggregate (geomean
//! over the suite) — i.e. the tuner has to actually route, not just
//! dodge disasters. (The executed-path equality of the pricing core is
//! separately property-tested in `tests/autoplan_integration.rs`.)
//!
//! Run with `cargo bench --bench autoplan_selection`
//! (`MSREP_BENCH_QUICK=1` shrinks the matrices).

use msrep::autoplan::{compare_fixed_formats, plan_auto, AutoPlanOptions};
use msrep::coordinator::{Engine, RunConfig};
use msrep::formats::{gen, Matrix};
use msrep::report::Table;
use msrep::util::bench::section;
use msrep::util::stats::geomean;
use msrep::workload;

const REUSE: usize = 32;

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cfg = RunConfig::default();
    let engine = Engine::new(cfg.clone()).expect("engine");

    section("autoplan format selection — dgx1 x 8, p*-opt, reuse 32 (modeled)");
    let mut t = Table::new([
        "scenario", "chosen", "auto", "best", "median", "worst", "vs median",
    ]);
    let mut ratios: Vec<f64> = Vec::new();
    for s in workload::autoplan_scenarios() {
        let mut coo = workload::autoplan_scenario_matrix(&s);
        if quick {
            // quarter-scale regeneration of the same structure
            coo = match s.kind {
                "banded" => gen::banded(s.m / 4, s.n / 4, s.band, s.seed),
                "block-diagonal" => {
                    gen::block_diagonal(s.m / 4, s.blocks, s.nnz / 4, s.seed)
                }
                _ => gen::power_law(s.m / 4, s.n / 4, s.nnz / 4, s.r, s.seed),
            };
        }
        let input = Matrix::Coo(coo);

        let opts = AutoPlanOptions::for_config(&cfg).with_reuse(REUSE);
        let auto = plan_auto(&cfg, &input, &opts).expect("tuner runs");
        let cmp = compare_fixed_formats(&engine, &input, &auto).expect("comparison prices");

        // acceptance 1: never worse than the worst fixed format
        assert!(
            cmp.never_worse_than_worst(),
            "{}: auto {:.3e} worse than worst fixed {:.3e}",
            s.name,
            cmp.auto_s,
            cmp.worst()
        );
        // acceptance 2: the tuner prices with the engine's own model, so
        // its pick must BE the best fixed format, not merely close
        assert!(
            cmp.matches_best(),
            "{}: auto {:.3e} missed the best fixed {:.3e}",
            s.name,
            cmp.auto_s,
            cmp.best()
        );
        ratios.push(cmp.vs_median());
        t.row([
            s.name.to_string(),
            auto.choice().candidate.label(),
            format!("{:.3e} s", cmp.auto_s),
            format!("{:.3e} s", cmp.best()),
            format!("{:.3e} s", cmp.median()),
            format!("{:.3e} s", cmp.worst()),
            format!("{:.2}x", cmp.vs_median()),
        ]);
    }
    print!("{}", t.render());

    let g = geomean(&ratios);
    println!("tuner vs median fixed format: geomean {g:.3}x over {} scenarios", ratios.len());
    // acceptance 3: strictly beats the median fixed format in aggregate
    assert!(
        g > 1.0,
        "tuner must beat the median fixed format in aggregate (geomean {g:.3})"
    );
    println!("autoplan selection acceptance OK");
}
