//! Bench: paper Fig. 16 — workload-partitioning overhead.
//!
//! Prints the regenerated overhead table (% of end-to-end, per platform ×
//! format × mode) and micro-benchmarks the real partitioning code paths
//! (the host-side cost the three modes attribute differently, §4.1).

use msrep::coordinator::partitioner::{balanced, baseline};
use msrep::formats::FormatKind;
use msrep::report::figures::{self, SuiteCache};
use msrep::util::bench::{black_box, section, Bench};

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cache = if quick { SuiteCache::build_quick(2) } else { SuiteCache::build() };

    section("Fig. 16 — partitioning overhead (% of end-to-end, geomean over suite)");
    print!(
        "{}",
        figures::fig16_partition_overhead(&cache).expect("fig16").render()
    );

    section("real partitioning cost on the HV15R analog (host wall time)");
    let b = Bench::from_env();
    for format in FormatKind::ALL {
        let mat = cache.matrix("HV15R", format);
        type PartFn = fn(
            &msrep::formats::Matrix,
            usize,
        ) -> msrep::Result<msrep::coordinator::PartitionOutcome>;
        for (label, f) in [
            ("blocks", baseline as PartFn),
            ("nnz-balanced", balanced as PartFn),
        ] {
            let r = b.run(&format!("fig16/partition/{}/{label}/np8", format.name()), || {
                black_box(f(&mat, 8).unwrap())
            });
            println!("{}", r.render());
        }
    }
}
