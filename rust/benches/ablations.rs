//! Ablations over MSREP's design choices (the DESIGN.md §6 extras — not a
//! paper figure, but the studies the paper's §6 discussion implies):
//!
//!  1. merge-path crossover: on-GPU tree reduce vs CPU sum as the result
//!     vector grows (why the paper's column merge is on-GPU at 1M+ rows);
//!  2. skew sensitivity: nnz-balanced vs row-block imbalance as the
//!     power-law exponent R varies;
//!  3. bucket padding waste: what the ×4 nnz-bucket spacing costs;
//!  4. two-level vs naive placement under partial GPU counts.

use msrep::coordinator::partitioner::{balanced, baseline};
use msrep::formats::{convert, gen, Matrix};
use msrep::report::Table;
use msrep::runtime::buckets;
use msrep::sim::{model, Platform};
use msrep::util::bench::section;
use msrep::util::stats::imbalance;

fn main() {
    ablation_merge_crossover();
    ablation_skew_sensitivity();
    ablation_padding_waste();
    ablation_numa_partial_counts();
    ablation_scaleout();
    ablation_spmm_amortization();
}

fn ablation_scaleout() {
    use msrep::coordinator::scaleout::{scaleout_spmv, ScaleOutScheme};
    use msrep::sim::Cluster;

    section("ablation 5 — scale-out: MSREP two-level vs broadcast all-gather [39]");
    let csr = convert::to_csr(&Matrix::Coo(gen::power_law(8_192, 8_192, 800_000, 2.0, 77)));
    let mut t = Table::new(["nodes", "msrep-2level speedup", "broadcast[39] speedup"]);
    let base_ms = scaleout_spmv(&Cluster::summit(1), &csr, ScaleOutScheme::MsrepPartialMerge)
        .unwrap()
        .total;
    let base_bc = scaleout_spmv(&Cluster::summit(1), &csr, ScaleOutScheme::BroadcastAllGather)
        .unwrap()
        .total;
    for nodes in [1usize, 2, 4, 8, 16] {
        let ms = scaleout_spmv(&Cluster::summit(nodes), &csr, ScaleOutScheme::MsrepPartialMerge)
            .unwrap()
            .total;
        let bc = scaleout_spmv(&Cluster::summit(nodes), &csr, ScaleOutScheme::BroadcastAllGather)
            .unwrap()
            .total;
        t.row([
            nodes.to_string(),
            format!("{:.2}x", base_ms / ms),
            format!("{:.2}x", base_bc / bc),
        ]);
    }
    print!("{}", t.render());
    println!("(the broadcast scheme's all-gather is what caps Yang et al.'s scaling — paper §7)");
}

fn ablation_spmm_amortization() {
    use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
    use msrep::formats::FormatKind;

    section("ablation 6 — SpMM stream amortization vs K independent SpMV (paper §2.3)");
    let coo = gen::power_law(4_096, 4_096, 500_000, 2.0, 78);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let eng = Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap();
    let x1 = gen::dense_vector(4_096, 79);
    let t_spmv = eng.spmv(&mat, &x1, 1.0, 0.0, None).unwrap().metrics.modeled_total;
    let mut t = Table::new(["K", "K x SpMV", "SpMM", "speedup"]);
    for k in [2usize, 4, 8, 16] {
        let xk = gen::dense_vector(4_096 * k, 80 + k as u64);
        let t_spmm = eng.spmm(&mat, &xk, k, 1.0, 0.0, None).unwrap().metrics.modeled_total;
        t.row([
            k.to_string(),
            format!("{:.1} µs", k as f64 * t_spmv * 1e6),
            format!("{:.1} µs", t_spmm * 1e6),
            format!("{:.2}x", k as f64 * t_spmv / t_spmm),
        ]);
    }
    print!("{}", t.render());
}

fn ablation_merge_crossover() {
    section("ablation 1 — column-merge path: GPU tree reduce vs CPU sum (np=8, DGX-1)");
    let p = Platform::dgx1();
    let mut t = Table::new(["rows m", "tree reduce", "cpu sum", "winner"]);
    for m in [1_000usize, 10_000, 100_000, 1_000_000, 10_000_000] {
        let bytes = (m * 4) as u64;
        let tree = model::gpu_tree_reduce_time(&p, 8, bytes)
            + model::lone_transfer_time(&p, bytes);
        let cpu = model::lone_transfer_time(&p, bytes) + model::cpu_vector_sum_time(&p, 8, bytes);
        t.row([
            m.to_string(),
            format!("{:.2} µs", tree * 1e6),
            format!("{:.2} µs", cpu * 1e6),
            if tree < cpu { "tree" } else { "cpu" }.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("(the paper's >=1M-row matrices sit firmly on the tree side)");
}

fn ablation_skew_sensitivity() {
    section("ablation 2 — load imbalance vs power-law exponent R (np=8)");
    let mut t = Table::new(["R", "row-block imbalance", "nnz-balanced imbalance"]);
    for r in [1.2f64, 1.6, 2.0, 2.6, 3.2] {
        let coo = gen::power_law(8_192, 8_192, 400_000, r, (r * 10.0) as u64);
        let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
        let blocks = baseline(&mat, 8).unwrap();
        let bal = balanced(&mat, 8).unwrap();
        t.row([
            format!("{r:.1}"),
            format!("{:.3}", imbalance(&blocks.loads())),
            format!("{:.3}", imbalance(&bal.loads())),
        ]);
    }
    print!("{}", t.render());
}

fn ablation_padding_waste() {
    section("ablation 3 — AOT bucket padding waste across the suite partition sizes");
    let mut t = Table::new(["partition nnz", "bucket", "waste x"]);
    for nnz in [987_000usize / 8, 750_000 / 6, 120_000, 40_000, 5_000] {
        let b = buckets::nnz_bucket(nnz).unwrap();
        t.row([
            nnz.to_string(),
            b.to_string(),
            format!("{:.2}", buckets::padding_waste(nnz, b)),
        ]);
    }
    print!("{}", t.render());
}

fn ablation_numa_partial_counts() {
    section("ablation 4 — NUMA-aware H2D advantage at partial GPU counts (Summit)");
    let p = Platform::summit();
    let mut t = Table::new(["gpus", "naive max-transfer", "aware max-transfer", "gain"]);
    for np in 1..=6usize {
        let bytes: Vec<u64> = (0..p.num_gpus)
            .map(|g| if g < np { 10_000_000 } else { 0 })
            .collect();
        let naive = vec![0usize; p.num_gpus];
        let aware: Vec<usize> = p.gpu_numa.clone();
        let t_naive = model::concurrent_h2d_times(&p, &bytes, &naive)
            .into_iter()
            .fold(0.0, f64::max);
        let t_aware = model::concurrent_h2d_times(&p, &bytes, &aware)
            .into_iter()
            .fold(0.0, f64::max);
        t.row([
            np.to_string(),
            format!("{:.1} µs", t_naive * 1e6),
            format!("{:.1} µs", t_aware * 1e6),
            format!("{:.2}x", t_naive / t_aware),
        ]);
    }
    print!("{}", t.render());
}
