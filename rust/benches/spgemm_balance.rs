//! Bench: SpGEMM planning balance — nnz-balanced vs flop-balanced plans
//! on skewed sparse×sparse products (the DESIGN.md §10 acceptance sweep:
//! the flop plan's max-GPU numeric time must beat the nnz plan's on every
//! skewed square, and the win must grow with the tail weight).
//!
//! Run with `cargo bench --bench spgemm_balance`
//! (`MSREP_BENCH_QUICK=1` shrinks the inputs).

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::report::Table;
use msrep::sim::{model, Platform};
use msrep::util::bench::section;
use msrep::workload;

fn engine(np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .expect("engine")
}

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let (m, nnz) = if quick { (1_500, 25_000) } else { (6_000, 120_000) };

    section(&format!(
        "A·A flop-vs-nnz planning — dgx1, {m} nodes, ~{nnz} edges, exponent sweep (modeled)"
    ));
    let mut t = Table::new([
        "R",
        "gpus",
        "flop imb (nnz)",
        "flop imb (flops)",
        "numeric (nnz)",
        "numeric (flops)",
        "speedup",
    ]);
    let mut heavier_wins: Vec<f64> = vec![];
    for &r in &[2.4f64, 1.6] {
        let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(m, m, nnz, r, 42))));
        let mut best = 0.0f64;
        for np in [2usize, 4, 8] {
            let eng = engine(np);
            let by_nnz = eng
                .spgemm_with_plan(&eng.plan(&a).expect("nnz plan"), &a)
                .expect("nnz-plan product");
            let by_flops = eng
                .spgemm_with_plan(&eng.plan_spgemm(&a, &a).expect("flop plan"), &a)
                .expect("flop-plan product");
            assert!(
                by_flops.metrics.t_numeric < by_nnz.metrics.t_numeric,
                "R={r} np={np}: flop plan must beat nnz plan"
            );
            let speedup = model::speedup(by_nnz.metrics.t_numeric, by_flops.metrics.t_numeric);
            best = best.max(speedup);
            t.row([
                format!("{r:.1}"),
                np.to_string(),
                format!("{:.3}", by_nnz.metrics.flop_imbalance),
                format!("{:.3}", by_flops.metrics.flop_imbalance),
                format!("{:.3e} s", by_nnz.metrics.t_numeric),
                format!("{:.3e} s", by_flops.metrics.t_numeric),
                format!("{speedup:.2}x"),
            ]);
        }
        heavier_wins.push(best);
    }
    print!("{}", t.render());
    assert!(
        heavier_wins[1] >= heavier_wins[0],
        "heavier tail (R=1.6) should gain at least as much as R=2.4: {heavier_wins:?}"
    );

    section("scenario chains — flop-balanced execution (modeled)");
    let mut t = Table::new(["scenario", "stages", "flops", "nnz(C)", "compression", "total"]);
    for s in workload::spgemm_scenarios() {
        let chain = workload::spgemm_scenario_chain(&s);
        let eng = engine(8);
        let mut acc = chain[0].clone();
        let (mut flops, mut c_nnz, mut total, mut stages) = (0u64, 0u64, 0.0f64, 0usize);
        for b in &chain[1..] {
            let rep = eng.spgemm(&acc, b).expect("scenario product");
            flops += rep.metrics.flops;
            c_nnz = rep.metrics.c_nnz;
            total += rep.metrics.modeled_total;
            stages += 1;
            acc = Matrix::Csr(rep.c);
        }
        t.row([
            s.name.to_string(),
            stages.to_string(),
            flops.to_string(),
            c_nnz.to_string(),
            format!("{:.3}", if flops == 0 { 1.0 } else { c_nnz as f64 / flops as f64 }),
            format!("{total:.3e} s"),
        ]);
    }
    print!("{}", t.render());
}
