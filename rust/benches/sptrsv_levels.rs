//! Bench: level-scheduled SpTRSV — level-balanced wavefront split vs
//! naive row blocks across GPU counts (the DESIGN.md §11 acceptance
//! sweep: the level split's modeled kernel time — Σ over levels of the
//! max-GPU wavefront — must beat the row-block split on a skewed factor),
//! plus the deep-vs-wide factor regime where the inter-level sync term
//! takes over.
//!
//! Run with `cargo bench --bench sptrsv_levels`
//! (`MSREP_BENCH_QUICK=1` shrinks the factors).

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{gen, FormatKind, Matrix};
use msrep::report::Table;
use msrep::sim::Platform;
use msrep::sptrsv::{triangular_of, SptrsvSplit, Triangle};
use msrep::util::bench::section;

fn engine(np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .expect("engine")
}

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let (m, nnz) = if quick { (1_000, 15_000) } else { (4_000, 60_000) };

    // heavy-tailed lower factor: the skew that concentrates whole
    // wavefronts on few GPUs under naive row-block ownership
    let skewed = Matrix::Csr(triangular_of(
        &Matrix::Coo(gen::power_law(m, m, nnz, 1.5, 42)),
        Triangle::Lower,
        1.0,
    ));
    let b = gen::dense_vector(m, 43);

    section(&format!(
        "SpTRSV wavefront split — dgx1, skewed lower factor, {m} rows, ~{} nnz (modeled)",
        skewed.nnz()
    ));
    let mut t = Table::new([
        "gpus",
        "levels",
        "kernels (rows)",
        "kernels (levels)",
        "speedup",
        "sync share (levels)",
    ]);
    for np in [2, 4, 8] {
        let eng = engine(np);
        let lvl_plan = eng.plan_sptrsv(&skewed, Triangle::Lower).expect("level plan");
        let row_plan = eng
            .plan_sptrsv_with_split(&skewed, Triangle::Lower, SptrsvSplit::RowBlocks)
            .expect("row plan");
        let by_level = eng.sptrsv_with_plan(&lvl_plan, &b).expect("level solve");
        let by_rows = eng.sptrsv_with_plan(&row_plan, &b).expect("row solve");
        assert_eq!(by_level.x, by_rows.x, "np={np}: split policy must not change numerics");
        assert!(
            by_level.metrics.t_levels < by_rows.metrics.t_levels,
            "np={np}: level-balanced kernels must beat naive row blocks \
             ({} vs {})",
            by_level.metrics.t_levels,
            by_rows.metrics.t_levels
        );
        t.row([
            np.to_string(),
            by_level.metrics.levels.to_string(),
            format!("{:.3e} s", by_rows.metrics.t_levels),
            format!("{:.3e} s", by_level.metrics.t_levels),
            format!("{:.2}x", by_rows.metrics.t_levels / by_level.metrics.t_levels),
            format!(
                "{:.1}%",
                100.0 * by_level.metrics.t_sync / by_level.metrics.modeled_total
            ),
        ]);
    }
    print!("{}", t.render());

    section("deep vs wide factors — where the inter-level sync term takes over (dgx1 x8)");
    let band = if quick { 300 } else { 1_200 };
    let deep = Matrix::Csr(triangular_of(
        &Matrix::Coo(gen::banded(band, band, 5, 44)),
        Triangle::Lower,
        1.0,
    ));
    let wide = Matrix::Csr(triangular_of(
        &Matrix::Coo(gen::uniform(band, band, 3 * band, 45)),
        Triangle::Lower,
        1.0,
    ));
    let eng = engine(8);
    let bb = gen::dense_vector(band, 46);
    for (name, factor) in [("banded (deep)", &deep), ("uniform (wide)", &wide)] {
        let rep = eng.sptrsv(factor, &bb, Triangle::Lower).expect("solve");
        println!(
            "{name:<16} levels {:>5} | mean par {:>8.1} | kernels {:.3e} s | sync {:.3e} s \
             ({:.1}% of total)",
            rep.metrics.levels,
            rep.metrics.mean_parallelism,
            rep.metrics.t_levels,
            rep.metrics.t_sync,
            100.0 * rep.metrics.t_sync / rep.metrics.modeled_total,
        );
    }
}
