//! Bench: paper Fig. 20 — effect of NUMA awareness on scaling.
//!
//! Prints the regenerated speedup-vs-GPUs series (NUMA-aware vs naive
//! placement, com-Orkut analog, p\*-opt) for both platforms. The expected
//! shape: Summit saturates near 3 GPUs without NUMA awareness; DGX-1 is
//! largely indifferent (paper §5.6).

use msrep::report::figures::{self, SuiteCache};
use msrep::report::Series;
use msrep::util::bench::section;

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let cache = if quick { SuiteCache::build_quick(1) } else { SuiteCache::build() };

    section("Fig. 20 — NUMA awareness (com-Orkut analog, p*-opt)");
    for (platform, series) in figures::fig20_numa(&cache).expect("fig20") {
        println!("\n--- {platform} ---");
        print!("{}", Series::render_table(&series, "gpus"));
    }
}
