//! Bench: pSELL fill-ratio sweep — where the padded SELL-C-σ stream wins
//! and where it loses (the DESIGN.md §17 decision surface, made
//! executable).
//!
//! For each structure family the sweep reports the canonical C=32/σ=128
//! fill ratio next to the modeled max-GPU SpMV compute time of a pSELL
//! plan vs a pCSR plan on the same matrix, and asserts the acceptance
//! split: the regular stencils (2-D Laplacian, diagonal bands) must route
//! *to* pSELL — its padded stream at 0.70 kernel efficiency strictly
//! beats pCSR's dense stream — while the heavy-tailed power-law graphs
//! must route *away* (σ-window padding blows the stream up faster than
//! the efficiency edge pays for).
//!
//! Run with `cargo bench --bench psell_fill`
//! (`MSREP_BENCH_QUICK=1` shrinks the matrices; set `MSREP_BENCH_OUT` to
//! also write the sweep as a canonical `BENCH_*` envelope).

use std::collections::BTreeMap;

use msrep::coordinator::{model_spmv_phases, Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, Coo, FormatKind, Matrix, PSell};
use msrep::report::Table;
use msrep::sim::Platform;
use msrep::util::bench::{bench_record, section, write_bench_json, Bench};
use msrep::util::json::Value;

fn cfg(format: FormatKind) -> RunConfig {
    RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    }
}

/// Modeled max-GPU compute seconds of one format's plan on `coo`.
fn modeled_compute(coo: &Coo, format: FormatKind) -> f64 {
    let c = cfg(format);
    let mat = convert::to_format(&Matrix::Coo(coo.clone()), format);
    let plan = Engine::new(c.clone()).expect("engine").plan(&mat).expect("plan");
    model_spmv_phases(&c, &plan).t_compute
}

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let scale = if quick { 4 } else { 1 };

    // (name, matrix, psell_must_win) — fill decays down the table
    let grid = if quick { 64 } else { 128 };
    let sweep: Vec<(String, Coo, bool)> = vec![
        (format!("laplacian2d-{grid}x{grid}"), gen::laplacian_2d(grid), true),
        ("banded-b4".to_string(), gen::banded(4_096 / scale, 4_096 / scale, 4, 501), true),
        ("banded-b8".to_string(), gen::banded(4_096 / scale, 4_096 / scale, 8, 501), true),
        ("banded-b16".to_string(), gen::banded(4_096 / scale, 4_096 / scale, 16, 501), true),
        (
            "powerlaw-r1.6".to_string(),
            gen::power_law(8_192 / scale, 8_192 / scale, 250_000 / scale, 1.6, 502),
            false,
        ),
        (
            "powerlaw-r2.0".to_string(),
            gen::power_law(8_192 / scale, 8_192 / scale, 250_000 / scale, 2.0, 502),
            false,
        ),
    ];

    section("pSELL fill-ratio sweep — modeled max-GPU compute, dgx1 x 8, p*-opt");
    let mut t = Table::new(["structure", "nnz", "fill", "psell", "pcsr", "psell/pcsr", ""]);
    let mut rows = Vec::new();
    for (name, coo, psell_must_win) in &sweep {
        let fill = PSell::from_csr(&convert::to_csr(&Matrix::Coo(coo.clone()))).fill_ratio();
        let psell_s = modeled_compute(coo, FormatKind::PSell);
        let pcsr_s = modeled_compute(coo, FormatKind::Csr);
        let ratio = psell_s / pcsr_s;
        if *psell_must_win {
            assert!(
                psell_s < pcsr_s,
                "{name}: pSELL {psell_s:.3e}s must strictly beat pCSR {pcsr_s:.3e}s \
                 (fill {fill:.3})"
            );
        } else {
            assert!(
                psell_s > pcsr_s,
                "{name}: pSELL {psell_s:.3e}s must lose to pCSR {pcsr_s:.3e}s on a \
                 heavy tail (fill {fill:.3})"
            );
        }
        t.row([
            name.clone(),
            coo.nnz().to_string(),
            format!("{fill:.3}"),
            format!("{psell_s:.3e} s"),
            format!("{pcsr_s:.3e} s"),
            format!("{ratio:.2}x"),
            if *psell_must_win { "<- psell" } else { "<- pcsr" }.to_string(),
        ]);
        let mut rec = BTreeMap::new();
        rec.insert("structure".to_string(), Value::Str(name.clone()));
        rec.insert("nnz".to_string(), Value::Num(coo.nnz() as f64));
        rec.insert("fill".to_string(), Value::Num(fill));
        rec.insert("psell_s".to_string(), Value::Num(psell_s));
        rec.insert("pcsr_s".to_string(), Value::Num(pcsr_s));
        rows.push(Value::Obj(rec));
    }
    print!("{}", t.render());

    // host wall cost of the pSELL layout build itself (the honest side of
    // the t_partition model)
    section("pSELL layout build (host wall)");
    let b = Bench::from_env();
    let band = gen::banded(4_096 / scale, 4_096 / scale, 8, 501);
    let csr = convert::to_csr(&Matrix::Coo(band));
    let r = b.run("psell_fill/from_csr/banded-b8", || PSell::from_csr(&csr).padded());
    println!("{}", r.render());

    if let Ok(path) = std::env::var("MSREP_BENCH_OUT") {
        let mut root = BTreeMap::new();
        root.insert("rows".to_string(), Value::Arr(rows));
        root.insert("quick".to_string(), Value::Bool(quick));
        write_bench_json(&path, &bench_record("psell_fill", root)).expect("write bench json");
        println!("wrote {path}");
    }
    println!("psell fill-ratio acceptance OK");
}
