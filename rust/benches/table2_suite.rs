//! Bench: paper Table 2 — the evaluation-suite analogs.
//!
//! Prints the regenerated table (with the fitted power-law exponent of
//! each analog) and benchmarks matrix generation + the R estimator.

use msrep::formats::{gen, stats};
use msrep::report::figures::{self, SuiteCache};
use msrep::util::bench::{black_box, section, Bench};

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    section("Table 2 — evaluation suite analogs");
    let cache = if quick { SuiteCache::build_quick(2) } else { SuiteCache::build() };
    print!("{}", figures::table2(&cache).render());

    section("suite-substrate microbenchmarks");
    let b = Bench::from_env();
    let r = b.run("table2/power_law_gen_100k", || {
        black_box(gen::power_law(10_000, 10_000, 100_000, 2.0, 1))
    });
    println!("{}", r.render());
    let m = gen::power_law(10_000, 10_000, 100_000, 2.0, 1);
    let r = b.run("table2/profile_plus_r_fit", || black_box(stats::profile(&m)));
    println!("{}", r.render());
}
