//! Bench: solver plan-reuse amortization — planned-SpMV vs cold
//! re-partitioning per-iteration cost across GPU counts (the DESIGN.md §9
//! acceptance sweep: planned must beat cold on every preset, and the
//! amortization factor must grow with the plan's share of an iteration).
//!
//! Run with `cargo bench --bench solver_amortization`
//! (`MSREP_BENCH_QUICK=1` shrinks the system).

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::report::Table;
use msrep::sim::Platform;
use msrep::solver::{cg, pagerank, SolverConfig};
use msrep::spmv::spmv_matrix;
use msrep::util::bench::section;

fn engine(platform: Platform, np: usize) -> Engine {
    Engine::new(RunConfig {
        platform,
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .expect("engine")
}

fn main() {
    let quick = std::env::var("MSREP_BENCH_QUICK").is_ok();
    let (m, nnz) = if quick { (2_000, 30_000) } else { (10_000, 200_000) };

    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(m, nnz, 1.5, 42))));
    let x_star = gen::dense_vector(m, 43);
    let mut b = vec![0.0f32; m];
    spmv_matrix(&a, &x_star, 1.0, 0.0, &mut b).expect("reference rhs");

    section(&format!(
        "CG plan-reuse amortization — dgx1, {m} unknowns, ~{nnz} nnz (modeled)"
    ));
    let mut t =
        Table::new(["gpus", "iters", "plan build", "spmv/iter", "cold/iter", "amortization"]);
    for np in [1, 2, 4, 8] {
        let rep = cg(&engine(Platform::dgx1(), np), &a, &b, &SolverConfig::default())
            .expect("cg solve");
        assert!(rep.converged, "np={np}: CG must converge on the certified-SPD system");
        assert!(
            rep.planned_iter_cost() < rep.cold_iter_cost(),
            "np={np}: planned iteration must beat cold re-partitioning"
        );
        t.row([
            np.to_string(),
            rep.iterations.to_string(),
            format!("{:.3e} s", rep.t_plan),
            format!("{:.3e} s", rep.planned_iter_cost()),
            format!("{:.3e} s", rep.cold_iter_cost()),
            format!("{:.2}x", rep.amortization()),
        ]);
    }
    print!("{}", t.render());

    section("PageRank through the CSC transpose plan — summit x6 (modeled)");
    let links = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(
        m,
        m,
        nnz,
        2.1,
        44,
    ))));
    let cfg = SolverConfig { tol: 1e-6, max_iters: 200, ..Default::default() };
    let rep = pagerank(&engine(Platform::summit(), 6), &links, 0.85, &cfg).expect("pagerank");
    println!(
        "iters {} converged {} | spmv/iter {:.3e} s vs cold/iter {:.3e} s | amortization {:.2}x",
        rep.iterations,
        rep.converged,
        rep.planned_iter_cost(),
        rep.cold_iter_cost(),
        rep.amortization(),
    );
    assert!(rep.planned_iter_cost() < rep.cold_iter_cost());
}
