//! Differential tests for the measured execution backend (DESIGN.md §14):
//! the measured and modeled CPU backends must agree **bitwise** — same
//! kernels, same per-GPU fan-out, same fixed-order merge — and both must
//! agree with the sequential reference oracle, across every format ×
//! GPU count × op (SpMV, K-wide SpMM, level-scheduled SpTRSV), including
//! the adversarial shapes of `tests/properties.rs`. Solver runs (CG,
//! ILU(0)-PCG) must produce the same iterate trace on both backends.

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, Coo, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::spmv::spmv_matrix;
use msrep::sptrsv::{trsv_csr, triangular_of, Triangle};
use msrep::util::prop::{check, Gen};

const NP_GRID: [usize; 4] = [1, 2, 4, 8];

fn engine(backend: Backend, mode: Mode, format: FormatKind, np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode,
        format,
        backend,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_close_to_reference(got: &[f32], expect: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), expect.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(expect).enumerate() {
        let rel = (g - w).abs() / (1.0 + w.abs());
        assert!(rel <= tol, "{what}: row {i}: {g} vs {w} (rel {rel:.2e})");
    }
}

#[test]
fn spmv_measured_equals_modeled_equals_reference_across_grid() {
    let coo = gen::power_law(600, 600, 9_000, 1.9, 7);
    let x = gen::dense_vector(600, 8);
    let y0 = gen::dense_vector(600, 9);
    let (alpha, beta) = (1.3f32, 0.4f32);
    let mut expect = y0.clone();
    spmv_matrix(&Matrix::Coo(coo.clone()), &x, alpha, beta, &mut expect).unwrap();
    for fmt in FormatKind::ALL {
        let mat = convert::to_format(&Matrix::Coo(coo.clone()), fmt);
        for np in NP_GRID {
            let modeled = engine(Backend::CpuRef, Mode::PStarOpt, fmt, np);
            let measured = engine(Backend::Measured, Mode::PStarOpt, fmt, np);
            let a = modeled.spmv(&mat, &x, alpha, beta, Some(&y0)).unwrap();
            let b = measured.spmv(&mat, &x, alpha, beta, Some(&y0)).unwrap();
            let tag = format!("spmv {} np{np}", fmt.name());
            assert_eq!(bits(&a.y), bits(&b.y), "{tag}: backends diverged");
            assert_close_to_reference(&b.y, &expect, 1e-3, &tag);
            // the modeled timeline is backend-independent, bitwise
            assert_eq!(a.metrics.modeled_total.to_bits(), b.metrics.modeled_total.to_bits());
            assert_eq!(a.metrics.t_compute.to_bits(), b.metrics.t_compute.to_bits());
            assert_eq!(a.metrics.t_merge.to_bits(), b.metrics.t_merge.to_bits());
            // only the measured backend reports per-GPU kernel walls
            assert!(a.metrics.measured_busy.is_empty(), "{tag}: cpuref has no busy walls");
            assert_eq!(b.metrics.measured_busy.len(), np, "{tag}: one wall per GPU");
            assert!(b.metrics.measured_busy.iter().all(|w| w.is_finite() && *w >= 0.0));
        }
    }
}

#[test]
fn spmm_measured_equals_modeled_for_k_1_and_8() {
    let coo = gen::power_law(300, 300, 5_000, 2.0, 17);
    for fmt in FormatKind::ALL {
        let mat = convert::to_format(&Matrix::Coo(coo.clone()), fmt);
        for np in NP_GRID {
            for k in [1usize, 8] {
                let x = gen::dense_vector(300 * k, 18 + k as u64);
                let y0 = gen::dense_vector(300 * k, 19 + k as u64);
                let modeled = engine(Backend::CpuRef, Mode::PStar, fmt, np);
                let measured = engine(Backend::Measured, Mode::PStar, fmt, np);
                let a = modeled.spmm(&mat, &x, k, 0.9, 0.2, Some(&y0)).unwrap();
                let b = measured.spmm(&mat, &x, k, 0.9, 0.2, Some(&y0)).unwrap();
                let tag = format!("spmm {} np{np} k{k}", fmt.name());
                assert_eq!(bits(&a.y), bits(&b.y), "{tag}: backends diverged");
                assert_eq!(b.metrics.measured_busy.len(), np, "{tag}");
                // k-wide SpMM == k stacked SpMVs, column by column
                for j in 0..k {
                    let xj: Vec<f32> = (0..300).map(|i| x[i * k + j]).collect();
                    let yj: Vec<f32> = (0..300).map(|i| y0[i * k + j]).collect();
                    let mut expect = yj.clone();
                    spmv_matrix(&mat, &xj, 0.9, 0.2, &mut expect).unwrap();
                    let col: Vec<f32> = (0..300).map(|i| b.y[i * k + j]).collect();
                    assert_close_to_reference(&col, &expect, 1e-3, &format!("{tag} col{j}"));
                }
            }
        }
    }
}

#[test]
fn sptrsv_measured_equals_modeled_and_oracle() {
    let base = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(500, 500, 6_000, 1.8, 23))));
    let lower = triangular_of(&base, Triangle::Lower, 1.0);
    let b = gen::dense_vector(500, 24);
    let expect = trsv_csr(&lower, &b, Triangle::Lower).unwrap();
    for np in NP_GRID {
        let modeled = engine(Backend::CpuRef, Mode::PStarOpt, FormatKind::Csr, np);
        let measured = engine(Backend::Measured, Mode::PStarOpt, FormatKind::Csr, np);
        let mat = Matrix::Csr(lower.clone());
        let ra = modeled.sptrsv(&mat, &b, Triangle::Lower).unwrap();
        let rb = measured.sptrsv(&mat, &b, Triangle::Lower).unwrap();
        let tag = format!("sptrsv np{np}");
        assert_eq!(bits(&ra.x), bits(&rb.x), "{tag}: backends diverged");
        assert_close_to_reference(&rb.x, &expect, 1e-3, &tag);
        assert_eq!(
            ra.metrics.modeled_total.to_bits(),
            rb.metrics.modeled_total.to_bits(),
            "{tag}: modeled totals diverged"
        );
        // the level/sync walls are measured on both backends (the level
        // loop is shared) and must be finite
        for m in [&ra.metrics, &rb.metrics] {
            assert!(m.measured_levels.is_finite() && m.measured_levels >= 0.0, "{tag}");
            assert!(m.measured_sync.is_finite() && m.measured_sync >= 0.0, "{tag}");
        }
    }
}

/// Adversarial shapes from `tests/properties.rs`: 1×n, n×1, fully empty,
/// clustered duplicates — partitions with empty tasks, single-row
/// partitions, and zero-nnz GPUs all appear here.
fn arb_adversarial_coo(g: &mut Gen) -> Coo {
    let (m, n) = match g.usize_in(0..5) {
        0 => (1, g.usize_in(1..10 + g.size())),
        1 => (g.usize_in(1..10 + g.size()), 1),
        _ => (g.usize_in(1..10 + g.size()), g.usize_in(1..10 + g.size())),
    };
    if g.prob(0.25) {
        return Coo::empty(m, n);
    }
    let nnz = g.usize_in(0..2 * (m + n));
    let rows: Vec<u32> = (0..nnz).map(|_| (g.usize_in(0..m) / 2 * 2 % m) as u32).collect();
    let cols: Vec<u32> = (0..nnz).map(|_| (g.usize_in(0..n) / 2 * 2 % n) as u32).collect();
    let vals = g.vec_f32(nnz);
    Coo::new(m, n, rows, cols, vals).unwrap()
}

#[test]
fn prop_backends_agree_bitwise_on_adversarial_shapes() {
    check("measured == modeled on adversarial shapes", 60, |g| {
        let coo = arb_adversarial_coo(g);
        let fmt = FormatKind::ALL[g.usize_in(0..3)];
        let np = [1, 2, 4, 8][g.usize_in(0..4)];
        let mode = [Mode::Baseline, Mode::PStar, Mode::PStarOpt][g.usize_in(0..3)];
        let mat = convert::to_format(&Matrix::Coo(coo), fmt);
        let x = gen::dense_vector(mat.cols(), g.rng().next_u64());
        let modeled = engine(Backend::CpuRef, mode, fmt, np);
        let measured = engine(Backend::Measured, mode, fmt, np);
        let a = modeled.spmv(&mat, &x, 1.7, 0.0, None).unwrap();
        let b = measured.spmv(&mat, &x, 1.7, 0.0, None).unwrap();
        assert_eq!(
            bits(&a.y),
            bits(&b.y),
            "{}x{} nnz {} {} np{np} {:?}",
            mat.rows(),
            mat.cols(),
            mat.nnz(),
            fmt.name(),
            mode
        );
        assert_eq!(b.metrics.measured_busy.len(), np);
    });
}

#[test]
fn cg_iterate_trace_is_identical_across_backends() {
    let spd = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(400, 4_000, 1.8, 31))));
    let x_star = gen::dense_vector(400, 32);
    let mut b = vec![0.0f32; 400];
    spmv_matrix(&spd, &x_star, 1.0, 0.0, &mut b).unwrap();
    let cfg = msrep::solver::SolverConfig {
        tol: 1e-6,
        max_iters: 200,
        plan_source: msrep::solver::PlanSource::Reused,
    };
    for np in [2usize, 8] {
        let modeled = engine(Backend::CpuRef, Mode::PStarOpt, FormatKind::Csr, np);
        let measured = engine(Backend::Measured, Mode::PStarOpt, FormatKind::Csr, np);
        let ra = msrep::solver::cg(&modeled, &spd, &b, &cfg).unwrap();
        let rb = msrep::solver::cg(&measured, &spd, &b, &cfg).unwrap();
        assert!(ra.converged && rb.converged, "np{np}: CG should converge on the SPD system");
        assert_eq!(ra.iterations, rb.iterations, "np{np}: iteration counts diverged");
        assert_eq!(bits(&ra.x), bits(&rb.x), "np{np}: final iterates diverged");
        assert_eq!(ra.trace.len(), rb.trace.len(), "np{np}");
        for (sa, sb) in ra.trace.iter().zip(&rb.trace) {
            assert_eq!(sa.iter, sb.iter);
            let rel = (sa.residual - sb.residual).abs() / sa.residual.abs().max(1e-300);
            assert!(
                rel <= 1e-12,
                "np{np} iter {}: residual {} vs {} (rel {rel:.2e})",
                sa.iter,
                sa.residual,
                sb.residual
            );
        }
    }
}

#[test]
fn ilu0_pcg_iterate_trace_is_identical_across_backends() {
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::laplacian_2d(20))));
    let x_star = gen::dense_vector(400, 33);
    let mut b = vec![0.0f32; 400];
    spmv_matrix(&a, &x_star, 1.0, 0.0, &mut b).unwrap();
    let cfg = msrep::solver::SolverConfig {
        tol: 1e-6,
        max_iters: 200,
        plan_source: msrep::solver::PlanSource::Reused,
    };
    for np in [2usize, 4] {
        let modeled = engine(Backend::CpuRef, Mode::PStarOpt, FormatKind::Csr, np);
        let measured = engine(Backend::Measured, Mode::PStarOpt, FormatKind::Csr, np);
        let ra = msrep::solver::pcg(&modeled, &a, &b, msrep::solver::Preconditioner::Ilu0, &cfg)
            .unwrap();
        let rb = msrep::solver::pcg(&measured, &a, &b, msrep::solver::Preconditioner::Ilu0, &cfg)
            .unwrap();
        assert!(ra.converged && rb.converged, "np{np}: PCG should converge on the stencil");
        assert_eq!(ra.iterations, rb.iterations, "np{np}");
        assert_eq!(bits(&ra.x), bits(&rb.x), "np{np}: final iterates diverged");
        for (sa, sb) in ra.trace.iter().zip(&rb.trace) {
            let rel = (sa.residual - sb.residual).abs() / sa.residual.abs().max(1e-300);
            assert!(rel <= 1e-12, "np{np} iter {}: rel {rel:.2e}", sa.iter);
        }
    }
}
