//! Observability integration tests: the zero-allocation contract of a
//! disabled recorder, the bitwise `envelope == modeled_total` barrier
//! contract of `*_with_plan` traces, per-lane span containment, Chrome
//! trace-event round-tripping through the home-grown JSON layer, and the
//! serve/solver span lifecycles (DESIGN.md §13).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::obs::{to_chrome_json, SpanKind, Trace, Track, TraceRecorder};
use msrep::serve::{ServeConfig, Server, SpmvRequest};
use msrep::sim::Platform;
use msrep::solver::{PlanSource, SolverConfig};
use msrep::sptrsv::{triangular_of, Triangle};
use msrep::util::prop::check;
use msrep::util::{json, stats};

// ---------------------------------------------------------------------------
// Counting allocator: proves the disabled recorder's no-op fast path.
// Only allocation *count* is tracked (per thread, so parallel tests don't
// interfere); frees are irrelevant to the zero-overhead contract.

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers all memory operations to `System`; the counter update is a
// plain thread-local Cell write and cannot itself allocate (const-init TLS).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCS.with(|c| c.get())
}

#[test]
fn disabled_recorder_allocates_nothing() {
    let rec = TraceRecorder::disabled();
    assert!(!rec.is_enabled());
    // Warm anything lazy (TLS slot, panic machinery) before measuring.
    rec.span(Track::Host, "warmup", SpanKind::Phase, 0.0, 1.0);
    let _ = rec.cursor();

    let before = allocations();
    for i in 0..1_000u32 {
        let t = f64::from(i);
        rec.span(Track::Host, "noop", SpanKind::Phase, t, t + 1.0);
        rec.span_with(
            rec.gpu(i as usize % 4),
            "noop",
            SpanKind::Dispatch,
            t,
            t + 1.0,
            &[("batch_k", 4.0)],
        );
        rec.marker(Track::Lane("serve queue"), "expired", t);
        rec.advance(1.0);
        rec.set_cursor(t);
        let _ = rec.cursor();
        let _ = rec.is_enabled();
    }
    let after = allocations();
    assert_eq!(
        after - before,
        0,
        "a disabled recorder must not allocate on any hot-path method"
    );
}

// ---------------------------------------------------------------------------
// Shared builders.

fn engine_on(np: usize, mode: Mode, format: FormatKind) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode,
        format,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap()
}

fn matrix_in(format: FormatKind, m: usize, nnz: usize, seed: u64) -> Matrix {
    let coo = gen::power_law(m, m, nnz, 2.0, seed);
    convert::to_format(&Matrix::Coo(coo), format)
}

/// Within every device lane, spans must tile without overlap: sorted by
/// start, each span ends no later than the next begins (barriers are
/// shared, so containment is exact, not approximate).
fn assert_gpu_lanes_sequential(trace: &Trace) {
    for track in trace.tracks() {
        if !matches!(track, Track::Gpu(_)) {
            continue;
        }
        let mut lane: Vec<(f64, f64)> = trace
            .spans()
            .iter()
            .filter(|s| s.track == track)
            .map(|s| (s.t_start, s.t_end))
            .collect();
        lane.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for w in lane.windows(2) {
            assert!(
                w[0].1 <= w[1].0,
                "{track:?}: span ending at {} overlaps next starting at {}",
                w[0].1,
                w[1].0
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Barrier contract: envelope == modeled_total, bitwise, for planned calls.

#[test]
fn spmv_with_plan_envelope_is_modeled_total_bitwise() {
    check("spmv planned envelope", 24, |g| {
        let m = 16 + g.size() * 13;
        let nnz = m * (2 + g.usize_in(0..6));
        let format = *g.choose(&FormatKind::ALL);
        let mode = *g.choose(&[Mode::Baseline, Mode::PStar, Mode::PStarOpt]);
        let np = 1 + g.usize_in(0..8);
        let seed = g.usize_in(0..1_000_000) as u64;

        let mat = matrix_in(format, m, nnz, seed);
        let x = gen::dense_vector(m, seed + 1);
        let mut engine = engine_on(np, mode, format);
        engine.set_recorder(TraceRecorder::enabled());
        let plan = engine.plan(&mat).unwrap();
        let rep = engine.spmv_with_plan(&plan, &x, 1.0, 0.0, None).unwrap();
        let trace = engine.recorder().take();

        assert!(!trace.is_empty());
        assert_eq!(
            trace.envelope(),
            rep.metrics.modeled_total,
            "{format:?} {mode:?} np={np}: planned-call envelope must be bitwise equal"
        );
        assert_gpu_lanes_sequential(&trace);
    });
}

#[test]
fn spgemm_with_plan_envelope_is_modeled_total_bitwise() {
    check("spgemm planned envelope", 10, |g| {
        let m = 24 + g.size() * 11;
        let nnz = m * (2 + g.usize_in(0..4));
        let np = 1 + g.usize_in(0..8);
        let seed = g.usize_in(0..1_000_000) as u64;

        let a = matrix_in(FormatKind::Csr, m, nnz, seed);
        let b = matrix_in(FormatKind::Csr, m, nnz, seed + 7);
        let mut engine = engine_on(np, Mode::PStarOpt, FormatKind::Csr);
        engine.set_recorder(TraceRecorder::enabled());
        let plan = engine.plan_spgemm(&a, &b).unwrap();
        let rep = engine.spgemm_with_plan(&plan, &b).unwrap();
        let trace = engine.recorder().take();

        assert_eq!(trace.envelope(), rep.metrics.modeled_total, "np={np}");
        assert_gpu_lanes_sequential(&trace);
    });
}

#[test]
fn sptrsv_with_plan_envelope_is_modeled_total_bitwise() {
    check("sptrsv planned envelope", 10, |g| {
        let m = 24 + g.size() * 11;
        let nnz = m * (2 + g.usize_in(0..4));
        let np = 1 + g.usize_in(0..8);
        let triangle = *g.choose(&[Triangle::Lower, Triangle::Upper]);
        let seed = g.usize_in(0..1_000_000) as u64;

        let base = matrix_in(FormatKind::Csr, m, nnz, seed);
        let factor = Matrix::Csr(triangular_of(&base, triangle, 1.0));
        let b = gen::dense_vector(m, seed + 3);
        let mut engine = engine_on(np, Mode::PStarOpt, FormatKind::Csr);
        engine.set_recorder(TraceRecorder::enabled());
        let plan = engine.plan_sptrsv(&factor, triangle).unwrap();
        let rep = engine.sptrsv_with_plan(&plan, &b).unwrap();
        let trace = engine.recorder().take();

        assert_eq!(trace.envelope(), rep.metrics.modeled_total, "np={np} {triangle:?}");
        assert_gpu_lanes_sequential(&trace);
    });
}

#[test]
fn one_shot_envelope_matches_modeled_total_approximately() {
    // One-shot calls prepend the partition span, which re-associates the
    // sum — equality holds only to rounding, not bitwise (DESIGN.md §13).
    let mat = matrix_in(FormatKind::Csr, 300, 3_000, 41);
    let x = gen::dense_vector(300, 42);
    let mut engine = engine_on(4, Mode::PStarOpt, FormatKind::Csr);
    engine.set_recorder(TraceRecorder::enabled());
    let rep = engine.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
    let trace = engine.recorder().take();
    let total = rep.metrics.modeled_total;
    assert!(
        (trace.envelope() - total).abs() <= 1e-12 * total.abs(),
        "one-shot envelope {} vs modeled_total {total}",
        trace.envelope()
    );
    // The partition phase must actually be in the trace.
    assert!(trace.spans().iter().any(|s| s.name == "partition"));
}

#[test]
fn engine_recorder_is_disabled_by_default() {
    let mat = matrix_in(FormatKind::Csr, 64, 300, 5);
    let x = gen::dense_vector(64, 6);
    let engine = engine_on(2, Mode::PStarOpt, FormatKind::Csr);
    assert!(!engine.recorder().is_enabled());
    engine.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
    assert!(engine.recorder().take().is_empty(), "no recorder, no spans");
}

// ---------------------------------------------------------------------------
// Chrome trace-event export round-trip.

#[test]
fn chrome_trace_round_trips_through_json() {
    let mat = matrix_in(FormatKind::Csr, 200, 2_000, 17);
    let x = gen::dense_vector(200, 18);
    let mut engine = engine_on(3, Mode::PStarOpt, FormatKind::Csr);
    engine.set_recorder(TraceRecorder::enabled());
    engine.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
    let trace = engine.recorder().take();

    let text = to_chrome_json(&trace).to_json();
    let parsed = json::parse(&text).unwrap();
    assert_eq!(parsed.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));

    let events = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    let phase = |e: &json::Value| e.get("ph").and_then(|v| v.as_str()).map(str::to_string);
    let metadata = events.iter().filter(|e| phase(e).as_deref() == Some("M")).count();
    let complete: Vec<&json::Value> =
        events.iter().filter(|e| phase(e).as_deref() == Some("X")).collect();
    assert_eq!(metadata, trace.tracks().len(), "one thread_name record per track");
    assert_eq!(complete.len(), trace.len(), "one complete event per span");

    // Reconstructing the envelope from ts+dur (microseconds) must agree
    // with the in-memory modeled envelope up to fp rounding. Skip the
    // measured overlay, which envelope() deliberately excludes.
    let measured: Vec<bool> = trace
        .spans()
        .iter()
        .map(|s| s.kind == SpanKind::Measured)
        .collect();
    let mut rebuilt: f64 = 0.0;
    for (e, skip) in complete.iter().zip(&measured) {
        if *skip {
            continue;
        }
        let ts = e.get("ts").and_then(|v| v.as_f64()).unwrap();
        let dur = e.get("dur").and_then(|v| v.as_f64()).unwrap();
        rebuilt = rebuilt.max((ts + dur) / 1e6);
    }
    let envelope = trace.envelope();
    assert!(
        (rebuilt - envelope).abs() <= 1e-9 * envelope.max(1e-12),
        "rebuilt {rebuilt} vs envelope {envelope}"
    );
}

#[test]
fn empty_trace_writes_valid_chrome_json_to_disk() {
    let path = std::env::temp_dir().join(format!("msrep-obs-empty-{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    msrep::obs::write_chrome_trace(&Trace::default(), &path).unwrap();
    let parsed = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(
        parsed.get("traceEvents").and_then(|v| v.as_arr()).map(Vec::len),
        Some(0),
        "an empty trace must still be a loadable document, not a write error"
    );
    assert_eq!(parsed.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn marker_only_tracks_export_as_zero_duration_events() {
    let rec = TraceRecorder::enabled();
    rec.marker(Track::Lane("plan cache"), "cache miss", 1e-3);
    rec.marker(Track::Lane("plan cache"), "cache hit", 2e-3);
    let trace = rec.take();

    let parsed = json::parse(&to_chrome_json(&trace).to_json()).unwrap();
    let events = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    let phase = |e: &json::Value| e.get("ph").and_then(|v| v.as_str()).map(str::to_string);
    // The marker-only lane still gets its thread_name metadata record...
    let metas: Vec<&json::Value> =
        events.iter().filter(|e| phase(e).as_deref() == Some("M")).collect();
    assert_eq!(metas.len(), 1);
    assert_eq!(
        metas[0].get("args").and_then(|a| a.get("name")).and_then(|v| v.as_str()),
        Some("plan cache")
    );
    // ...and each marker is a complete event of zero duration at its stamp.
    let xs: Vec<&json::Value> =
        events.iter().filter(|e| phase(e).as_deref() == Some("X")).collect();
    assert_eq!(xs.len(), 2);
    for e in &xs {
        assert_eq!(e.get("dur").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(e.get("cat").and_then(|v| v.as_str()), Some("marker"));
    }
    assert_eq!(xs[0].get("ts").and_then(|v| v.as_f64()), Some(1e-3 * 1e6));
}

#[test]
fn cloned_recorders_with_equal_gpu_base_share_one_chrome_lane() {
    // Two engines given the same base map their local GPU 0 onto the same
    // global ordinal — the export must merge them into one tid, not mint
    // a duplicate thread.
    let rec = TraceRecorder::enabled();
    let a = rec.with_gpu_base(4);
    let b = rec.with_gpu_base(4);
    a.span(a.gpu(0), "compute", SpanKind::Phase, 0.0, 1e-3);
    b.span(b.gpu(0), "compute", SpanKind::Phase, 2e-3, 3e-3);
    b.span(b.gpu(1), "compute", SpanKind::Phase, 2e-3, 3e-3);
    let trace = rec.take();
    assert_eq!(trace.len(), 3);
    assert_eq!(trace.tracks(), vec![Track::Gpu(4), Track::Gpu(5)]);

    let parsed = json::parse(&to_chrome_json(&trace).to_json()).unwrap();
    let events = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    let phase = |e: &json::Value| e.get("ph").and_then(|v| v.as_str()).map(str::to_string);
    let metas = events.iter().filter(|e| phase(e).as_deref() == Some("M")).count();
    assert_eq!(metas, 2, "one thread_name per distinct global lane");
    let tids: Vec<usize> = events
        .iter()
        .filter(|e| phase(e).as_deref() == Some("X"))
        .map(|e| e.get("tid").and_then(|v| v.as_usize()).unwrap())
        .collect();
    assert_eq!(tids, vec![0, 0, 1], "colliding clones share gpu 4's tid");
}

// ---------------------------------------------------------------------------
// Serve + solver span lifecycles.

#[test]
fn serve_run_emits_queue_dispatch_and_device_spans() {
    let cfg = ServeConfig {
        run: RunConfig {
            platform: Platform::dgx1(),
            num_gpus: 4,
            mode: Mode::PStarOpt,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: None,
        },
        num_engines: 2,
        max_batch: 4,
        flush_deadline_s: 50e-6,
        queue_capacity: 64,
        plan_cache_capacity: 4,
        cluster: None,
    };
    let mut server = Server::new(cfg).unwrap();
    let mat = matrix_in(FormatKind::Csr, 256, 3_000, 23);
    let id = server.register(mat);
    let recorder = TraceRecorder::enabled();
    server.set_recorder(&recorder);

    let reqs: Vec<SpmvRequest> = (0..12)
        .map(|i| SpmvRequest {
            matrix: id,
            x: gen::dense_vector(256, 100 + i),
            alpha: 1.0,
            arrival_s: i as f64 * 20e-6,
            deadline_s: None,
        })
        .collect();
    let report = server.run(reqs).unwrap();
    assert_eq!(report.completed, 12);

    let trace = recorder.take();
    let has = |pred: &dyn Fn(&msrep::obs::Span) -> bool| trace.spans().iter().any(pred);
    assert!(has(&|s| s.kind == SpanKind::Queue && s.track == Track::Lane("serve queue")));
    assert!(has(&|s| s.kind == SpanKind::Dispatch && matches!(s.track, Track::Engine(_))));
    assert!(
        has(&|s| matches!(s.track, Track::Gpu(_))),
        "dispatched batches must surface the engines' device spans"
    );
    // Every device lane carries a *global* ordinal: engine e's GPUs start
    // at e*num_gpus, so no lane index can reach past the pool.
    assert!(
        trace
            .spans()
            .iter()
            .all(|s| !matches!(s.track, Track::Gpu(g) if g >= 8)),
        "device lane ordinals must stay inside the 2-engine x 4-GPU pool"
    );
}

#[test]
fn solver_trace_overlays_iterations_on_the_solver_lane() {
    let m = 200;
    let spd = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(m, 2_000, 2.0, 31))));
    let rhs = gen::dense_vector(m, 32);
    let mut engine = engine_on(2, Mode::PStarOpt, FormatKind::Csr);
    engine.set_recorder(TraceRecorder::enabled());
    let cfg = SolverConfig { tol: 1e-5, max_iters: 50, plan_source: PlanSource::Reused };
    let report = msrep::solver::cg(&engine, &spd, &rhs, &cfg).unwrap();
    let trace = engine.recorder().take();

    let iters = trace
        .spans()
        .iter()
        .filter(|s| s.kind == SpanKind::Iteration && s.track == Track::Lane("solver"))
        .count();
    assert_eq!(iters, report.iterations, "one iteration span per CG iteration");
    assert!(
        trace
            .spans()
            .iter()
            .any(|s| s.track == Track::Lane("solver") && s.name == "plan"),
        "reused-plan solves trace the one-time planning cost"
    );
    assert_gpu_lanes_sequential(&trace);
}

// ---------------------------------------------------------------------------
// Stats satellites: NaN hygiene and the sortedness contract.

#[test]
fn summary_drops_non_finite_samples() {
    let s = stats::Summary::of(&[3.0, f64::NAN, 1.0, f64::INFINITY, 2.0]);
    assert_eq!(s.n, 3, "only finite samples count");
    assert_eq!(s.mean, 2.0);
    assert_eq!(s.min, 1.0);
    assert_eq!(s.max, 3.0);
    assert_eq!(s.median, 2.0);
}

#[test]
#[should_panic(expected = "no finite samples")]
fn summary_rejects_all_nan_input() {
    let _ = stats::Summary::of(&[f64::NAN, f64::NEG_INFINITY]);
}

#[test]
fn percentile_interpolates_on_sorted_input() {
    let sorted = [1.0, 2.0, 3.0, 4.0];
    assert_eq!(stats::percentile(&sorted, 0.0), 1.0);
    assert_eq!(stats::percentile(&sorted, 0.5), 2.5);
    assert_eq!(stats::percentile(&sorted, 1.0), 4.0);
    assert_eq!(stats::percentile(&[7.0], 0.95), 7.0);
}
