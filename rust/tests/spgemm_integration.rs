//! SpGEMM integration: the multi-GPU `C = A·B` matches the dense
//! reference product across every registered format (property
//! test), the Galerkin triple product works as a chain, and — the
//! planning acceptance — flop-balanced plans beat nnz-balanced plans on
//! a skewed power-law A·A under the sim cost model.

use msrep::coordinator::{Backend, Engine, Mode, RunConfig, WorkModel};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::spgemm::spgemm_csr;
use msrep::util::prop::check;
use msrep::workload;

fn engine(np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .expect("engine")
}

/// f64 dense reference of A·B.
fn dense_product(a: &Matrix, b: &Matrix) -> Vec<Vec<f64>> {
    let da = convert::to_coo(a).to_dense();
    let db = convert::to_coo(b).to_dense();
    let (m, kk, n) = (a.rows(), a.cols(), b.cols());
    let mut c = vec![vec![0.0f64; n]; m];
    for i in 0..m {
        for k in 0..kk {
            let v = da[i][k] as f64;
            if v != 0.0 {
                for (j, cij) in c[i].iter_mut().enumerate() {
                    *cij += v * db[k][j] as f64;
                }
            }
        }
    }
    c
}

fn assert_matches_dense(got: &msrep::formats::Csr, want: &[Vec<f64>], ctx: &str) {
    let dg = got.to_dense();
    assert_eq!(dg.len(), want.len(), "{ctx}: row count");
    for (i, (rg, rw)) in dg.iter().zip(want).enumerate() {
        assert_eq!(rg.len(), rw.len(), "{ctx}: col count");
        for (j, (a, b)) in rg.iter().zip(rw).enumerate() {
            assert!(
                (*a as f64 - b).abs() < 3e-3 * (1.0 + b.abs()),
                "{ctx}: ({i},{j}): {a} vs {b}"
            );
        }
    }
}

#[test]
fn spgemm_matches_dense_reference_property_all_formats() {
    check("spgemm == dense A·B", 24, |g| {
        let m = g.usize_in(2..4 + g.size() * 3);
        let kk = g.usize_in(2..4 + g.size() * 3);
        let n = g.usize_in(2..4 + g.size() * 3);
        let seed = g.usize_in(0..1_000_000) as u64;
        let nnz_a = g.usize_in(1..2 + m * kk / 2);
        let nnz_b = g.usize_in(1..2 + kk * n / 2);
        let a_coo = gen::uniform(m, kk, nnz_a, seed);
        let b = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::uniform(kk, n, nnz_b, seed + 1))));
        let expect = dense_product(&Matrix::Coo(a_coo.clone()), &b);
        let np = *g.choose(&[1usize, 2, 4, 8]);
        for format in FormatKind::ALL {
            let a = convert::to_format(&Matrix::Coo(a_coo.clone()), format);
            let rep = engine(np).spgemm(&a, &b).expect("spgemm");
            assert_matches_dense(&rep.c, &expect, &format!("{format:?}/np{np}/seed{seed}"));
        }
        // col-sorted COO exercises the element-split / column-merge path
        let mut col_sorted = a_coo.clone();
        col_sorted.sort_by_col();
        let rep = engine(np).spgemm(&Matrix::Coo(col_sorted), &b).expect("col-sorted spgemm");
        assert_matches_dense(&rep.c, &expect, &format!("coo-col/np{np}/seed{seed}"));
    });
}

#[test]
fn spgemm_agrees_with_reference_oracle() {
    let a = convert::to_csr(&Matrix::Coo(gen::power_law(400, 400, 6_000, 2.0, 17)));
    let oracle = spgemm_csr(&a, &a).unwrap();
    let rep = engine(8).spgemm(&Matrix::Csr(a.clone()), &Matrix::Csr(a)).unwrap();
    // identical structure, near-identical values
    assert_eq!(rep.c.row_ptr, oracle.row_ptr);
    assert_eq!(rep.c.col_idx, oracle.col_idx);
    for (x, y) in rep.c.val.iter().zip(&oracle.val) {
        assert!((x - y).abs() < 3e-3 * (1.0 + y.abs()), "{x} vs {y}");
    }
}

#[test]
fn galerkin_triple_product_matches_dense_and_stays_symmetric() {
    // two-grid AMG setup on an 8x8 Poisson stencil: C = R·A·P
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::laplacian_2d(8))));
    let p_coo = gen::aggregation_2d(8);
    let p = Matrix::Csr(convert::to_csr(&Matrix::Coo(p_coo.clone())));
    let r = Matrix::Csr(convert::to_csr(&Matrix::Coo(p_coo.transpose())));
    let eng = engine(4);
    let ra = eng.spgemm(&r, &a).unwrap();
    let rap = eng.spgemm(&Matrix::Csr(ra.c), &p).unwrap();
    assert_eq!((rap.c.rows(), rap.c.cols()), (16, 16));
    // dense f64 reference of the full chain
    let ra_dense = dense_product(&r, &a);
    let dp = convert::to_coo(&p).to_dense();
    let mut expect = vec![vec![0.0f64; 16]; 16];
    for i in 0..16 {
        for k in 0..64 {
            if ra_dense[i][k] != 0.0 {
                for (j, e) in expect[i].iter_mut().enumerate() {
                    *e += ra_dense[i][k] * dp[k][j] as f64;
                }
            }
        }
    }
    assert_matches_dense(&rap.c, &expect, "galerkin");
    // the Galerkin coarse operator of an SPD stencil is symmetric
    let d = rap.c.to_dense();
    for i in 0..16 {
        for j in 0..16 {
            assert!((d[i][j] - d[j][i]).abs() < 1e-3, "asymmetry at ({i},{j})");
        }
    }
}

#[test]
fn workload_chains_run_end_to_end() {
    // smallest scenario end to end through the engine (the full set runs
    // in benches/spgemm_balance.rs and the CLI)
    let s = workload::spgemm_scenario_by_name("galerkin-rap").unwrap();
    let chain = workload::spgemm_scenario_chain(&s);
    let eng = engine(8);
    let mut acc = chain[0].clone();
    for b in &chain[1..] {
        let rep = eng.spgemm(&acc, b).unwrap();
        assert!(rep.metrics.modeled_total > 0.0);
        acc = Matrix::Csr(rep.c);
    }
    assert_eq!((acc.rows(), acc.cols()), (256, 256));
}

#[test]
fn flop_balanced_planning_beats_nnz_balanced_on_skewed_square() {
    // the acceptance scenario: heavy-tailed A·A, where per-row flops
    // decouple from per-row nnz
    let coo = gen::power_law(2_000, 2_000, 30_000, 1.6, 91);
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let eng = engine(8);
    let nnz_plan = eng.plan(&a).unwrap();
    let flop_plan = eng.plan_spgemm(&a, &a).unwrap();
    assert_eq!(nnz_plan.work, WorkModel::Nnz);
    assert_eq!(flop_plan.work, WorkModel::SpgemmFlops);
    let by_nnz = eng.spgemm_with_plan(&nnz_plan, &a).unwrap();
    let by_flops = eng.spgemm_with_plan(&flop_plan, &a).unwrap();
    // planning must not change the numerics
    assert_eq!(by_nnz.c.row_ptr, by_flops.c.row_ptr);
    assert_eq!(by_nnz.c.col_idx, by_flops.c.col_idx);
    // nnz-balanced partitions are flop-imbalanced on this input...
    assert!(
        by_nnz.metrics.flop_imbalance > 1.15,
        "input not skewed enough: {}",
        by_nnz.metrics.flop_imbalance
    );
    assert!(
        by_flops.metrics.flop_imbalance < by_nnz.metrics.flop_imbalance,
        "flop plan {} vs nnz plan {}",
        by_flops.metrics.flop_imbalance,
        by_nnz.metrics.flop_imbalance
    );
    // ...so the flop-balanced plan's max-GPU numeric time is strictly
    // lower under the sim cost model (the acceptance criterion)
    assert!(
        by_flops.metrics.t_numeric < by_nnz.metrics.t_numeric,
        "numeric phase: flops {} vs nnz {}",
        by_flops.metrics.t_numeric,
        by_nnz.metrics.t_numeric
    );
}
