//! End-to-end tests of the perf observatory (DESIGN.md §15): record
//! round-trip through the history file, gate stability on an unchanged
//! tree, and injected-slowdown detection with span-level attribution.
//!
//! The suite replays read the `MSREP_PERF_INJECT` env hook, so every test
//! that runs the suite serializes on one lock — the injection test must
//! never leak its sleep into the clean-tree ones.

use std::sync::Mutex;

use msrep::perf::{self, FindingKind, GateConfig, PerfOptions, PerfRecord, Workloads};
use msrep::util::bench::{append_bench_jsonl, read_last_bench_record};

static ENV_LOCK: Mutex<()> = Mutex::new(());

const INJECT_VAR: &str = "MSREP_PERF_INJECT";

fn opts(reps: usize) -> PerfOptions {
    let mut o = PerfOptions::quick();
    o.reps = reps;
    o
}

/// Loose enough that honest host noise never trips it (10 ms absolute
/// floor, 50% relative floor), tight enough that the 50 ms injection
/// below is unmissable.
fn loose_gate() -> GateConfig {
    GateConfig { k_sigma: 10.0, rel_floor: 0.5, abs_floor_s: 10e-3 }
}

#[test]
fn record_round_trips_through_the_history_file() {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var(INJECT_VAR);

    let o = opts(2);
    let record = perf::run_suite(&o).unwrap();
    assert_eq!(record.ops.len(), perf::suite::OP_NAMES.len());
    assert_eq!(record.suite, "quick");
    assert_eq!(record.suite_digest.len(), 16);
    for op in &record.ops {
        assert!(!op.modeled.is_empty(), "{}: no modeled phases", op.name);
        assert!(!op.measured.is_empty(), "{}: no measured phases", op.name);
        for (phase, st) in &op.measured {
            assert_eq!(st.n, 2, "{}/{phase}", op.name);
            assert!(st.median >= 0.0 && st.mad >= 0.0, "{}/{phase}", op.name);
        }
    }

    let path = std::env::temp_dir().join(format!("msrep-perf-it-{}.jsonl", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    std::fs::remove_file(&path).ok();
    let value = record.to_value();
    append_bench_jsonl(&path, &value).unwrap();
    append_bench_jsonl(&path, &value).unwrap();
    let lines = std::fs::read_to_string(&path).unwrap();
    assert_eq!(lines.lines().count(), 2, "history must append, not overwrite");
    let back = PerfRecord::from_value(&read_last_bench_record(&path).unwrap()).unwrap();
    assert_eq!(back, record);
    std::fs::remove_file(&path).ok();
}

#[test]
fn unchanged_tree_passes_the_gate_twice() {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var(INJECT_VAR);

    let o = opts(3);
    let spec = perf::suite::spec(&o.suite).unwrap();
    let w = Workloads::build(&spec).unwrap();
    let base = perf::run_suite_on(&o, &w).unwrap();
    let cur = perf::run_suite_on(&o, &w).unwrap();
    let cmp = perf::compare(&base, &cur, &loose_gate()).unwrap();
    assert!(cmp.modeled_checked > 0, "no modeled phases were compared");
    assert!(cmp.measured_checked > 0, "no measured phases were compared");
    assert!(
        cmp.passed(),
        "clean re-run tripped the gate: {:?}",
        cmp.gating()
    );
}

#[test]
fn injected_slowdown_is_flagged_and_attributed_to_phase_and_lane() {
    let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    std::env::remove_var(INJECT_VAR);

    let o = opts(3);
    let spec = perf::suite::spec(&o.suite).unwrap();
    let w = Workloads::build(&spec).unwrap();
    let base = perf::run_suite_on(&o, &w).unwrap();

    // 50 ms into GPU 1's exec phase — far past max(10·sigma, 50%, 10 ms)
    std::env::set_var(INJECT_VAR, "exec:1:50000");
    let cur = perf::run_suite_on(&o, &w);
    std::env::remove_var(INJECT_VAR);
    let cur = cur.unwrap();

    let cmp = perf::compare(&base, &cur, &loose_gate()).unwrap();
    assert!(!cmp.passed(), "injected slowdown passed the gate");
    let finding = cmp
        .findings
        .iter()
        .find(|f| {
            f.kind == FindingKind::MeasuredRegression
                && f.op == "spmv/mouse_gene"
                && f.phase == "exec"
        })
        .expect("spmv exec regression not flagged");
    assert!(finding.current > finding.baseline + finding.threshold);

    // attribution re-runs traced under the same injection, so the worst
    // lane must be the injected one
    std::env::set_var(INJECT_VAR, "exec:1:50000");
    let report = perf::attribution::attribute(finding, &w, &o.platform, o.num_gpus, o.mode);
    std::env::remove_var(INJECT_VAR);
    let report = report.unwrap();
    assert!(report.contains("attribution: spmv/mouse_gene / exec"), "{report}");
    assert!(report.contains("worst lane: gpu 1"), "{report}");
    assert!(report.contains("top "), "{report}");
}
