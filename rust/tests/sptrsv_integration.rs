//! SpTRSV + ILU(0)/PCG integration tests — the DESIGN.md §11 acceptance
//! criteria, end to end through the public API:
//!
//!  * the multi-GPU level-scheduled solve matches the dense substitution
//!    oracle across every registered format, both triangles, every mode;
//!  * ILU(0)-preconditioned CG reaches tol=1e-6 on the 2-D Laplacian
//!    scenario in strictly fewer iterations than plain CG;
//!  * the level-aware plan's modeled max-GPU kernel time beats a naive
//!    row-block split on a skewed triangular factor under the sim cost
//!    model;
//!  * plan reuse across right-hand sides charges the symbolic cost once.

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::solver::{cg, ilu0, pcg, Preconditioner, SolverConfig};
use msrep::spmv::spmv_matrix;
use msrep::sptrsv::{dense_trsv, diagonally_dominant, triangular_of, SptrsvSplit, Triangle};

fn engine(mode: Mode, np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap()
}

fn matrix_in(format: FormatKind, csr: &msrep::formats::Csr) -> Matrix {
    convert::to_format(&Matrix::Csr(csr.clone()), format)
}

#[test]
fn sptrsv_matches_dense_oracle_across_formats_triangles_modes() {
    let base = gen::power_law(300, 300, 4_000, 1.8, 71);
    for triangle in [Triangle::Lower, Triangle::Upper] {
        // dominance keeps the f32 solve provably close to the f64 oracle
        let factor =
            diagonally_dominant(&triangular_of(&Matrix::Coo(base.clone()), triangle, 1.0), 0.5);
        let b = gen::dense_vector(300, 72);
        let expect = dense_trsv(&factor.to_dense(), &b, triangle).unwrap();
        for format in FormatKind::ALL {
            let mat = matrix_in(format, &factor);
            for mode in Mode::ALL {
                for np in [1, 4, 8] {
                    let rep = engine(mode, np).sptrsv(&mat, &b, triangle).unwrap();
                    for (i, (got, want)) in rep.x.iter().zip(&expect).enumerate() {
                        assert!(
                            (*got as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
                            "{triangle:?}/{format:?}/{mode:?}/np{np} x[{i}]: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn ilu0_pcg_beats_plain_cg_on_the_laplacian_scenario() {
    // the workload scenario system: 64x64 Poisson, tol 1e-6
    let s = msrep::workload::solver_scenario_by_name("poisson2d-pcg").unwrap();
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(msrep::workload::scenario_matrix(&s))));
    let x_star = gen::dense_vector(a.rows(), 73);
    let mut b = vec![0.0f32; a.rows()];
    spmv_matrix(&a, &x_star, 1.0, 0.0, &mut b).unwrap();
    let cfg = SolverConfig { tol: s.tol, max_iters: s.max_iters, ..Default::default() };
    let eng = engine(Mode::PStarOpt, 8);
    let plain = cg(&eng, &a, &b, &cfg).unwrap();
    let pre = pcg(&eng, &a, &b, Preconditioner::Ilu0, &cfg).unwrap();
    assert!(plain.converged, "CG residual {}", plain.final_residual);
    assert!(pre.converged, "PCG residual {}", pre.final_residual);
    assert!(pre.final_residual <= 1e-6);
    assert!(
        pre.iterations < plain.iterations,
        "ILU(0)-PCG took {} iterations vs CG's {}",
        pre.iterations,
        plain.iterations
    );
    // both reach the manufactured solution
    for (i, (got, want)) in pre.x.iter().zip(&x_star).enumerate() {
        assert!((got - want).abs() < 1e-2 * (1.0 + want.abs()), "x[{i}]: {got} vs {want}");
    }
}

#[test]
fn level_plan_beats_naive_row_split_on_skewed_factor() {
    // the acceptance comparison under the sim cost model: Σ over levels
    // of the max-GPU wavefront time
    let factor = Matrix::Csr(triangular_of(
        &Matrix::Coo(gen::power_law(3_000, 3_000, 45_000, 1.5, 74)),
        Triangle::Lower,
        1.0,
    ));
    let b = gen::dense_vector(3_000, 75);
    let eng = engine(Mode::PStarOpt, 8);
    let lvl = eng.plan_sptrsv(&factor, Triangle::Lower).unwrap();
    let rows = eng
        .plan_sptrsv_with_split(&factor, Triangle::Lower, SptrsvSplit::RowBlocks)
        .unwrap();
    let by_level = eng.sptrsv_with_plan(&lvl, &b).unwrap();
    let by_rows = eng.sptrsv_with_plan(&rows, &b).unwrap();
    assert_eq!(by_level.x, by_rows.x, "the split must not change numerics");
    assert!(
        by_level.metrics.t_levels < by_rows.metrics.t_levels,
        "level split {} vs row blocks {}",
        by_level.metrics.t_levels,
        by_rows.metrics.t_levels
    );
    // identical sync charges: the schedule (and so the barrier count) is
    // split-independent
    assert!((by_level.metrics.t_sync - by_rows.metrics.t_sync).abs() < 1e-15);
}

#[test]
fn sptrsv_plan_reuse_across_right_hand_sides() {
    let factor = Matrix::Csr(triangular_of(
        &Matrix::Coo(gen::power_law(500, 500, 7_000, 1.8, 76)),
        Triangle::Lower,
        1.0,
    ));
    let eng = engine(Mode::PStarOpt, 4);
    let plan = eng.plan_sptrsv(&factor, Triangle::Lower).unwrap();
    let csr = convert::to_csr(&factor);
    for seed in [80u64, 81, 82] {
        let b = gen::dense_vector(500, seed);
        let rep = eng.sptrsv_with_plan(&plan, &b).unwrap();
        // no symbolic charge on replay
        assert_eq!(rep.metrics.t_partition, 0.0);
        let expect = msrep::sptrsv::trsv_csr(&csr, &b, Triangle::Lower).unwrap();
        for (got, want) in rep.x.iter().zip(&expect) {
            assert!((got - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    }
}

#[test]
fn two_engine_solves_invert_the_ilu0_product_exactly() {
    // the PCG preconditioner step z = U⁻¹(L⁻¹ r) must be a true solve of
    // (L·U) z = r: push z back through the materialized product and
    // recover r
    let a = convert::to_csr(&Matrix::Coo(gen::laplacian_2d(16)));
    let (l, u) = ilu0(&a).unwrap();
    let lu = msrep::spgemm::spgemm_csr(&l, &u).unwrap();
    let eng = engine(Mode::PStarOpt, 4);
    let l_plan = eng.plan_sptrsv(&Matrix::Csr(l), Triangle::Lower).unwrap();
    let u_plan = eng.plan_sptrsv(&Matrix::Csr(u), Triangle::Upper).unwrap();
    let r = gen::dense_vector(a.rows(), 77);
    let fwd = eng.sptrsv_with_plan(&l_plan, &r).unwrap();
    let z = eng.sptrsv_with_plan(&u_plan, &fwd.x).unwrap();
    let mut back = vec![0.0f32; a.rows()];
    spmv_matrix(&Matrix::Csr(lu), &z.x, 1.0, 0.0, &mut back).unwrap();
    for (i, (got, want)) in back.iter().zip(&r).enumerate() {
        assert!(
            (got - want).abs() < 1e-3 * (1.0 + want.abs()),
            "(L·U) z diverges from r at {i}: {got} vs {want}"
        );
    }
}
