//! Property-based tests over the coordinator's core invariants, driven by
//! the in-crate `util::prop` harness (proptest is unavailable offline —
//! see DESIGN.md §3). Each property runs a deterministic seeded sweep;
//! failures print the replay seed.
//!
//! Invariants covered (the ones the paper's correctness rests on):
//!  * partitions tile `[0, nnz)` exactly — no loss, no overlap (Alg. 2/4/6)
//!  * per-partition loads differ by at most one non-zero (nnz balance)
//!  * local pointer arrays are monotone and consistent with the range
//!  * partition → execute → merge reproduces the exact SpMV for every
//!    format × strategy × np (routing/batching/state correctness)
//!  * pCSR merge metadata is self-sufficient (merge back to the original CSR)
//!  * CG on generated SPD systems converges to the dense reference
//!    solution in every partitioned format (solver-over-plan correctness)
//!  * CSR↔CSC↔COO conversion round-trips and `transpose(transpose(A)) ==
//!    A` hold on adversarial shapes — empty rows/cols, fully empty
//!    matrices, duplicate COO entries, 1×n and n×1
//!  * the level-scheduled SpTRSV matches the dense substitution oracle
//!    in every partitioned format, both triangles

use msrep::coordinator::partitioner::{balanced, baseline};
use msrep::coordinator::{merge, Engine, Mode, RunConfig};
use msrep::coordinator::{Backend, FormatKind};
use msrep::formats::{convert, gen, merge_row_partials, Coo, Csr, Matrix, PCoo, PCsc, PCsr};
use msrep::sim::Platform;
use msrep::spmv::spmv_matrix;
use msrep::util::prop::{check, Gen};

/// Random sparse matrix: size/density/skew all drawn from the generator.
fn arb_coo(g: &mut Gen) -> Coo {
    let m = g.usize_in(1..40 + g.size() * 8);
    let n = g.usize_in(1..40 + g.size() * 8);
    let nnz = g.usize_in(0..(m * n).min(60 + g.size() * 30));
    match g.usize_in(0..3) {
        0 => gen::uniform(m, n, nnz, g.rng().next_u64()),
        1 => gen::power_law(m, n, nnz.max(1), 1.0 + 2.5 * g.rng().f64(), g.rng().next_u64()),
        _ => {
            if m >= 2 {
                gen::two_band(m, n, nnz.max(2), 1.0 + 9.0 * g.rng().f64(), g.rng().next_u64())
            } else {
                gen::uniform(m, n, nnz, g.rng().next_u64())
            }
        }
    }
}

#[test]
fn prop_pcsr_partitions_tile_nnz_exactly() {
    check("pcsr tiles [0,nnz)", 60, |g| {
        let coo = arb_coo(g);
        let csr = Csr::from_coo(&coo);
        let np = g.usize_in(1..12);
        let parts = PCsr::partition(&csr, np).unwrap();
        assert_eq!(parts.len(), np);
        assert_eq!(parts[0].start_idx, 0);
        assert_eq!(parts.last().unwrap().end_idx, csr.nnz());
        for w in parts.windows(2) {
            assert_eq!(w[0].end_idx, w[1].start_idx, "gap/overlap");
        }
        // nnz balance: loads differ by at most 1
        let loads: Vec<usize> = parts.iter().map(|p| p.nnz()).collect();
        let (lo, hi) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(hi - lo <= 1, "loads {loads:?}");
    });
}

#[test]
fn prop_pcsc_pcoo_tile_and_balance() {
    check("pcsc/pcoo tile and balance", 40, |g| {
        let coo = arb_coo(g);
        let np = g.usize_in(1..10);
        let csc = convert::to_csc(&Matrix::Coo(coo.clone()));
        let parts = PCsc::partition(&csc, np).unwrap();
        assert_eq!(parts.iter().map(|p| p.nnz()).sum::<usize>(), csc.nnz());
        for w in parts.windows(2) {
            assert_eq!(w[0].end_idx, w[1].start_idx);
        }
        let mut row_sorted = coo.clone();
        row_sorted.sort_by_row();
        let parts = PCoo::partition(&row_sorted, np).unwrap();
        assert_eq!(parts.iter().map(|p| p.nnz()).sum::<usize>(), coo.nnz());
        let loads: Vec<usize> = parts.iter().map(|p| p.nnz()).collect();
        let (lo, hi) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(hi - lo <= 1);
    });
}

#[test]
fn prop_local_row_ptr_consistent() {
    check("pcsr local row_ptr", 60, |g| {
        let coo = arb_coo(g);
        let csr = Csr::from_coo(&coo);
        let np = g.usize_in(1..10);
        for p in PCsr::partition(&csr, np).unwrap() {
            assert_eq!(p.row_ptr[0], 0);
            assert_eq!(*p.row_ptr.last().unwrap(), p.nnz());
            assert!(p.row_ptr.windows(2).all(|w| w[0] <= w[1]));
            // every local (row, k) maps back to the right global nnz
            let ids = p.local_row_ids();
            assert_eq!(ids.len(), p.nnz());
            if p.nnz() > 0 {
                assert!((*ids.iter().max().unwrap() as usize) < p.local_rows());
            }
        }
    });
}

#[test]
fn prop_merge_pcsr_roundtrip() {
    check("merge pCSR back to CSR", 40, |g| {
        let coo = arb_coo(g);
        let csr = Csr::from_coo(&coo);
        let np = g.usize_in(1..8);
        let parts = PCsr::partition(&csr, np).unwrap();
        let merged = convert::merge_pcsr(&csr, &parts).unwrap();
        assert_eq!(merged.row_ptr, csr.row_ptr);
    });
}

#[test]
fn prop_partition_execute_merge_equals_reference() {
    check("partition+merge == SpMV", 40, |g| {
        let coo = arb_coo(g);
        let format = *g.choose(&FormatKind::ALL);
        // COO keeps its duplicates and exercises both sort orders; the
        // other formats go through the registry converter
        let mat = if format == FormatKind::Coo {
            let mut c = coo;
            if g.prob(0.5) {
                c.sort_by_col();
            } else {
                c.sort_by_row();
            }
            Matrix::Coo(c)
        } else {
            convert::to_format(&Matrix::Coo(coo), format)
        };
        let np = g.usize_in(1..9);
        let use_balanced = g.prob(0.7);
        let out = if use_balanced { balanced(&mat, np) } else { baseline(&mat, np) };
        let out = match out {
            Ok(o) => o,
            // baseline COO rejects col-sorted input by contract
            Err(_) => return,
        };
        let n = mat.cols();
        let m = mat.rows();
        let x = gen::dense_vector(n, g.rng().next_u64());
        let alpha = g.f32_in(-2.0, 2.0);
        let beta = g.f32_in(-2.0, 2.0);
        let y0 = gen::dense_vector(m, g.rng().next_u64());

        // execute each task with the plain stream loop
        let partials: Vec<Vec<f32>> = out
            .tasks
            .iter()
            .map(|t| {
                let mut py = vec![0.0f32; t.out_len];
                for k in 0..t.nnz() {
                    py[t.row_idx[k] as usize] += alpha * t.val[k] * x[t.col_idx[k] as usize];
                }
                py
            })
            .collect();
        let mut y = y0.clone();
        merge::merge(&out.tasks, &partials, beta, &mut y).unwrap();

        let mut expect = y0;
        spmv_matrix(&mat, &x, alpha, beta, &mut expect).unwrap();
        for (i, (a, b)) in y.iter().zip(&expect).enumerate() {
            assert!(
                (a - b).abs() < 3e-3 * (1.0 + b.abs()),
                "{format:?} np={np} balanced={use_balanced} row {i}: {a} vs {b}"
            );
        }
    });
}

#[test]
fn prop_engine_modes_agree_with_each_other() {
    check("all modes produce the same y", 15, |g| {
        let coo = arb_coo(g);
        let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
        let x = gen::dense_vector(mat.cols(), g.rng().next_u64());
        let np = g.usize_in(1..7);
        let mut results = vec![];
        for mode in Mode::ALL {
            let eng = Engine::new(RunConfig {
                platform: Platform::summit(),
                num_gpus: np.min(6),
                mode,
                format: FormatKind::Csr,
                backend: Backend::CpuRef,
                numa_aware: None,
                strategy_override: None,
            })
            .unwrap();
            results.push(eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap().y);
        }
        for w in results.windows(2) {
            for (a, b) in w[0].iter().zip(&w[1]) {
                assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
            }
        }
    });
}

#[test]
fn prop_merge_row_partials_linear_in_beta() {
    check("row merge is affine in beta", 30, |g| {
        let coo = arb_coo(g);
        let csr = Csr::from_coo(&coo);
        let np = g.usize_in(1..6);
        let parts = PCsr::partition(&csr, np).unwrap();
        let partials: Vec<Vec<f32>> = parts
            .iter()
            .map(|p| g.vec_f32(p.local_rows()))
            .collect();
        let y0 = g.vec_f32(csr.rows());
        let mut y_b0 = y0.clone();
        merge_row_partials(&parts, &partials, 0.0, &mut y_b0).unwrap();
        let mut y_b2 = y0.clone();
        merge_row_partials(&parts, &partials, 2.0, &mut y_b2).unwrap();
        // affine: y(beta) = y(0) + beta*y0
        for i in 0..csr.rows() {
            let want = y_b0[i] + 2.0 * y0[i];
            assert!((y_b2[i] - want).abs() < 1e-4 * (1.0 + want.abs()));
        }
    });
}

/// Dense Gaussian elimination with partial pivoting in f64 — the exact
/// reference the CG property compares against.
fn dense_solve(a: &[Vec<f32>], b: &[f32]) -> Vec<f64> {
    let n = b.len();
    let mut m: Vec<Vec<f64>> =
        a.iter().map(|row| row.iter().map(|&v| v as f64).collect()).collect();
    let mut rhs: Vec<f64> = b.iter().map(|&v| v as f64).collect();
    for col in 0..n {
        let piv = (col..n)
            .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
            .unwrap();
        m.swap(col, piv);
        rhs.swap(col, piv);
        let pivot_row = m[col].clone();
        let pivot_rhs = rhs[col];
        let d = pivot_row[col];
        for row in col + 1..n {
            let f = m[row][col] / d;
            if f != 0.0 {
                for (mk, pk) in m[row].iter_mut().zip(&pivot_row).skip(col) {
                    *mk -= f * pk;
                }
                rhs[row] -= f * pivot_rhs;
            }
        }
    }
    let mut x = vec![0.0f64; n];
    for row in (0..n).rev() {
        let mut s = rhs[row];
        for (k, xk) in x.iter().enumerate().skip(row + 1) {
            s -= m[row][k] * xk;
        }
        x[row] = s / m[row][row];
    }
    x
}

#[test]
fn prop_cg_matches_dense_solution_across_formats() {
    check("cg == dense solve, all formats", 12, |g| {
        let n = g.usize_in(2..20 + g.size());
        let coo = gen::spd(n, n * (2 + g.usize_in(0..4)), 2.0, g.rng().next_u64());
        let dense = coo.to_dense();
        let x_star = g.vec_f32(n);
        // rhs rounded to f32 so CG and the reference solve the same system
        let b: Vec<f32> = dense
            .iter()
            .map(|row| {
                row.iter().zip(&x_star).map(|(a, x)| *a as f64 * *x as f64).sum::<f64>() as f32
            })
            .collect();
        let x_ref = dense_solve(&dense, &b);
        let np = g.usize_in(1..9);
        let cfg = msrep::solver::SolverConfig { tol: 1e-7, max_iters: 400, ..Default::default() };
        for format in FormatKind::ALL {
            let mat = convert::to_format(&Matrix::Coo(coo.clone()), format);
            let eng = Engine::new(RunConfig {
                platform: Platform::dgx1(),
                num_gpus: np,
                mode: Mode::PStarOpt,
                format,
                backend: Backend::CpuRef,
                numa_aware: None,
                strategy_override: None,
            })
            .unwrap();
            let rep = msrep::solver::cg(&eng, &mat, &b, &cfg).unwrap();
            assert!(rep.converged, "{format:?} np={np} residual {}", rep.final_residual);
            for i in 0..n {
                assert!(
                    (rep.x[i] as f64 - x_ref[i]).abs() < 1e-3 * (1.0 + x_ref[i].abs()),
                    "{format:?} np={np} x[{i}]: {} vs {}",
                    rep.x[i],
                    x_ref[i]
                );
            }
        }
    });
}

/// Adversarial matrix generator for the conversion properties: draws
/// degenerate shapes (1×n, n×1, empty matrices) and structures (empty
/// rows/cols, duplicate coordinates) far more often than `arb_coo` does.
fn arb_adversarial_coo(g: &mut Gen) -> Coo {
    let (m, n) = match g.usize_in(0..5) {
        0 => (1, g.usize_in(1..10 + g.size())), // 1×n
        1 => (g.usize_in(1..10 + g.size()), 1), // n×1
        _ => (g.usize_in(1..10 + g.size()), g.usize_in(1..10 + g.size())),
    };
    if g.prob(0.25) {
        return Coo::empty(m, n); // fully empty
    }
    // cluster coordinates into few rows/cols so empty rows/cols AND
    // duplicate entries both appear with high probability
    let nnz = g.usize_in(0..2 * (m + n));
    let rows: Vec<u32> = (0..nnz).map(|_| (g.usize_in(0..m) / 2 * 2 % m) as u32).collect();
    let cols: Vec<u32> = (0..nnz).map(|_| (g.usize_in(0..n) / 2 * 2 % n) as u32).collect();
    let vals = g.vec_f32(nnz);
    Coo::new(m, n, rows, cols, vals).unwrap()
}

#[test]
fn prop_conversion_roundtrips_on_adversarial_shapes() {
    check("format round-trips on adversarial shapes", 80, |g| {
        let coo = arb_adversarial_coo(g);
        let dense = coo.to_dense();
        let as_mat = Matrix::Coo(coo.clone());
        // CSR↔CSC↔COO: every conversion chain lands on the same dense
        let csr = convert::to_csr(&as_mat);
        let csc = convert::to_csc(&as_mat);
        assert_eq!(csr.to_dense(), dense, "COO->CSR");
        assert_eq!(csc.to_dense(), dense, "COO->CSC");
        assert_eq!(convert::to_csc(&Matrix::Csr(csr.clone())).to_dense(), dense, "CSR->CSC");
        assert_eq!(convert::to_csr(&Matrix::Csc(csc.clone())).to_dense(), dense, "CSC->CSR");
        assert_eq!(convert::to_coo(&Matrix::Csr(csr.clone())).to_dense(), dense, "CSR->COO");
        assert_eq!(convert::to_coo(&Matrix::Csc(csc.clone())).to_dense(), dense, "CSC->COO");
        // nnz is conserved even with duplicates (conversions never merge)
        assert_eq!(csr.nnz(), coo.nnz());
        assert_eq!(csc.nnz(), coo.nnz());

        // transpose(transpose(A)) == A: exact array equality — transpose
        // is a storage reinterpretation, so the double application must
        // restore the original arrays, not just the same dense content
        let tt_csr = convert::transpose(&convert::transpose(&Matrix::Csr(csr.clone())));
        match tt_csr {
            Matrix::Csr(back) => {
                assert_eq!(back.row_ptr, csr.row_ptr);
                assert_eq!(back.col_idx, csr.col_idx);
                assert_eq!(back.val, csr.val);
            }
            other => panic!("CSR double transpose changed format to {:?}", other.kind()),
        }
        let tt_csc = convert::transpose(&convert::transpose(&Matrix::Csc(csc.clone())));
        match tt_csc {
            Matrix::Csc(back) => {
                assert_eq!(back.col_ptr, csc.col_ptr);
                assert_eq!(back.row_idx, csc.row_idx);
                assert_eq!(back.val, csc.val);
            }
            other => panic!("CSC double transpose changed format to {:?}", other.kind()),
        }
        let tt_coo = convert::transpose(&convert::transpose(&as_mat));
        match tt_coo {
            Matrix::Coo(back) => {
                assert_eq!(back.row_idx, coo.row_idx);
                assert_eq!(back.col_idx, coo.col_idx);
                assert_eq!(back.val, coo.val);
            }
            other => panic!("COO double transpose changed format to {:?}", other.kind()),
        }
        // single transpose flips shape and dense content
        let t = convert::transpose(&as_mat);
        assert_eq!((t.rows(), t.cols()), (coo.cols(), coo.rows()));
        let td = convert::to_coo(&t).to_dense();
        for i in 0..coo.rows() {
            for j in 0..coo.cols() {
                assert_eq!(td[j][i], dense[i][j], "transpose content at ({i},{j})");
            }
        }
    });
}

#[test]
fn prop_to_format_canonicalizes_duplicates_and_roundtrips() {
    check("to_format dedups COO and round-trips", 80, |g| {
        let coo = arb_adversarial_coo(g);
        let dense = coo.to_dense();
        let as_mat = Matrix::Coo(coo.clone());
        for format in FormatKind::ALL {
            let routed = convert::to_format(&as_mat, format);
            assert_eq!(routed.kind(), format);
            // same dense content, and no duplicate coordinate survives
            // the canonicalization in any target format
            let back = convert::to_coo(&routed);
            assert_eq!(back.to_dense(), dense, "{format:?} content");
            let mut seen = std::collections::BTreeSet::new();
            for (&r, &c) in back.row_idx.iter().zip(&back.col_idx) {
                assert!(seen.insert((r, c)), "{format:?}: duplicate ({r},{c}) survived");
            }
            // converting the canonical form again is stable: same nnz,
            // same dense content (the dedup pass is idempotent)
            let again = convert::to_format(&Matrix::Coo(back.clone()), format);
            assert_eq!(again.nnz(), back.nnz(), "{format:?} canonical nnz unstable");
            assert_eq!(convert::to_coo(&again).to_dense(), dense, "{format:?} re-route");
        }
        // duplicate-free COO passes through bitwise (the equivalence-lock
        // precondition: legacy callers see the exact same arrays)
        let clean = convert::to_coo(&convert::to_format(&as_mat, FormatKind::Coo));
        if let Matrix::Coo(back) = convert::to_format(&Matrix::Coo(clean.clone()), FormatKind::Coo)
        {
            assert_eq!(back.row_idx, clean.row_idx);
            assert_eq!(back.col_idx, clean.col_idx);
            assert_eq!(back.val, clean.val);
        } else {
            panic!("COO routed to a different format");
        }
    });
}

#[test]
fn prop_sptrsv_matches_dense_oracle_across_formats() {
    use msrep::sptrsv::{dense_trsv, diagonally_dominant, triangular_of, Triangle};
    check("sptrsv == dense substitution, all formats", 25, |g| {
        let n = g.usize_in(2..25 + g.size());
        let base = gen::power_law(
            n,
            n,
            g.usize_in(n..4 * n + 1),
            1.2 + 2.0 * g.rng().f64(),
            g.rng().next_u64(),
        );
        let triangle = if g.prob(0.5) { Triangle::Lower } else { Triangle::Upper };
        // dominance keeps the f32 solve provably close to the f64 oracle
        let factor = diagonally_dominant(
            &triangular_of(&Matrix::Coo(base), triangle, 1.0 + g.f32_in(0.0, 2.0)),
            0.5,
        );
        let b = g.vec_f32(n);
        let expect = dense_trsv(&factor.to_dense(), &b, triangle).unwrap();
        let np = g.usize_in(1..9);
        for format in FormatKind::ALL {
            let mat = convert::to_format(&Matrix::Csr(factor.clone()), format);
            let eng = Engine::new(RunConfig {
                platform: Platform::dgx1(),
                num_gpus: np,
                mode: Mode::PStarOpt,
                format,
                backend: Backend::CpuRef,
                numa_aware: None,
                strategy_override: None,
            })
            .unwrap();
            let rep = eng.sptrsv(&mat, &b, triangle).unwrap();
            for i in 0..n {
                assert!(
                    (rep.x[i] as f64 - expect[i]).abs() < 1e-3 * (1.0 + expect[i].abs()),
                    "{triangle:?} {format:?} np={np} x[{i}]: {} vs {}",
                    rep.x[i],
                    expect[i]
                );
            }
        }
    });
}

#[test]
fn prop_generator_invariants() {
    check("generators produce valid matrices", 50, |g| {
        let coo = arb_coo(g);
        // constructor-level invariants re-checked end to end
        assert!(coo.row_idx.iter().all(|&r| (r as usize) < coo.rows()));
        assert!(coo.col_idx.iter().all(|&c| (c as usize) < coo.cols()));
        assert_eq!(coo.row_idx.len(), coo.val.len());
        // conversions preserve nnz and dense content
        let csr = convert::to_csr(&Matrix::Coo(coo.clone()));
        let csc = convert::to_csc(&Matrix::Coo(coo.clone()));
        assert_eq!(csr.nnz(), coo.nnz());
        assert_eq!(csc.nnz(), coo.nnz());
        if coo.rows() * coo.cols() <= 4096 {
            assert_eq!(csr.to_dense(), coo.to_dense());
            assert_eq!(csc.to_dense(), coo.to_dense());
        }
    });
}
