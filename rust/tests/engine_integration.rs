//! Engine-level integration tests: suite-scale workloads, scaling shapes,
//! failure injection, and cross-mode/format agreement on the CpuRef
//! backend (the PJRT path is covered in runtime_integration.rs).

use msrep::coordinator::{Backend, Engine, Mode, RunConfig, Strategy};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::spmv::spmv_matrix;
use msrep::workload;

fn engine_on(platform: Platform, np: usize, mode: Mode, format: FormatKind) -> Engine {
    Engine::new(RunConfig {
        platform,
        num_gpus: np,
        mode,
        format,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap()
}

#[test]
fn suite_matrix_full_pipeline_all_formats() {
    // one real Table-2 analog end to end (hollywood: dense rows, high skew)
    let e = workload::by_name("hollywood-2009").unwrap();
    let coo = workload::suite_matrix(&e);
    let x = gen::dense_vector(e.m, 5);
    for format in FormatKind::ALL {
        let mat = convert::to_format(&Matrix::Coo(coo.clone()), format);
        let mut expect = vec![0.0f32; e.m];
        spmv_matrix(&mat, &x, 1.0, 0.0, &mut expect).unwrap();
        let rep = engine_on(Platform::summit(), 6, Mode::PStarOpt, format)
            .spmv(&mat, &x, 1.0, 0.0, None)
            .unwrap();
        let max_rel = rep
            .y
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f32, f32::max);
        assert!(max_rel < 5e-3, "{format:?}: {max_rel}");
        if format != FormatKind::PSell {
            // pSELL splits at σ-window granularity, so hollywood's skew
            // can't balance exactly — element-split formats must
            assert!(rep.metrics.imbalance < 1.01, "{format:?} must be nnz-balanced");
        }
    }
}

#[test]
fn scaling_shape_matches_paper_claims() {
    // p*-opt approaches linear; baseline does not improve materially.
    let e = workload::by_name("com-Orkut").unwrap();
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(workload::suite_matrix(&e))));
    let x = gen::dense_vector(e.m, 6);
    let total = |mode: Mode, np: usize| {
        engine_on(Platform::dgx1(), np, mode, FormatKind::Csr)
            .spmv(&mat, &x, 1.0, 0.0, None)
            .unwrap()
            .metrics
            .modeled_total
    };
    let t1 = total(Mode::PStarOpt, 1);
    let t8 = total(Mode::PStarOpt, 8);
    let speedup = t1 / t8;
    assert!(speedup > 5.0, "p*-opt 8-GPU speedup {speedup} (paper: 6.2)");
    let b1 = total(Mode::Baseline, 1);
    let b8 = total(Mode::Baseline, 8);
    assert!(
        b1 / b8 < 2.0,
        "baseline must not scale like p*-opt ({})",
        b1 / b8
    );
}

#[test]
fn numa_effect_is_summit_specific() {
    // paper §5.6: Summit cannot scale past ~3 GPUs without NUMA awareness;
    // DGX-1 shows no strong effect.
    let e = workload::by_name("com-Orkut").unwrap();
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(workload::suite_matrix(&e))));
    let x = gen::dense_vector(e.m, 7);
    let run = |platform: Platform, np: usize, aware: bool| {
        Engine::new(RunConfig {
            platform,
            num_gpus: np,
            mode: Mode::PStarOpt,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: Some(aware),
            strategy_override: None,
        })
        .unwrap()
        .spmv(&mat, &x, 1.0, 0.0, None)
        .unwrap()
        .metrics
        .modeled_total
    };
    // summit, naive: 6-GPU time barely beats 3-GPU time
    let s3 = run(Platform::summit(), 3, false);
    let s6 = run(Platform::summit(), 6, false);
    assert!(s6 > 0.75 * s3, "summit naive should saturate: t3 {s3} t6 {s6}");
    // summit, aware: 6 GPUs clearly beat 3
    let a3 = run(Platform::summit(), 3, true);
    let a6 = run(Platform::summit(), 6, true);
    assert!(a6 < 0.62 * a3, "summit aware should scale: t3 {a3} t6 {a6}");
    // dgx1: naive vs aware within 40% at 8 GPUs
    let d_naive = run(Platform::dgx1(), 8, false);
    let d_aware = run(Platform::dgx1(), 8, true);
    assert!(d_naive / d_aware < 1.4, "dgx1 NUMA effect too strong");
}

#[test]
fn fig6_imbalance_degrades_naive_throughput() {
    // ratio 1:10 should cost roughly half the balanced throughput
    // (paper Fig. 6: 559/1028 ≈ 0.54)
    let x_len = 4_096;
    let run = |ratio: f64| {
        let coo = gen::two_band(x_len, x_len, 400_000, ratio, 9);
        let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
        let x = gen::dense_vector(x_len, 10);
        Engine::new(RunConfig {
            platform: Platform::dgx1(),
            num_gpus: 8,
            mode: Mode::PStar,
            format: FormatKind::Csr,
            backend: Backend::CpuRef,
            numa_aware: None,
            strategy_override: Some(Strategy::Blocks),
        })
        .unwrap()
        .spmv(&mat, &x, 1.0, 0.0, None)
        .unwrap()
        .metrics
        .gflops()
    };
    let balanced = run(1.0);
    let skewed = run(10.0);
    let rel = skewed / balanced;
    assert!(
        (0.35..0.75).contains(&rel),
        "1:10 imbalance should roughly halve throughput, got {rel}"
    );
}

#[test]
fn coo_partition_overhead_dominates_baseline() {
    // §5.4: baseline COO partitioning costs 38–85% of end-to-end;
    // p*-opt collapses it by an order of magnitude.
    let e = workload::by_name("hollywood-2009").unwrap();
    let mat = Matrix::Coo(workload::suite_matrix(&e));
    let x = gen::dense_vector(e.m, 11);
    let frac = |mode: Mode| {
        engine_on(Platform::summit(), 6, mode, FormatKind::Coo)
            .spmv(&mat, &x, 1.0, 0.0, None)
            .unwrap()
            .metrics
            .partition_overhead()
    };
    let base = frac(Mode::Baseline);
    let opt = frac(Mode::PStarOpt);
    assert!(base > 0.3, "baseline COO partition overhead {base}");
    assert!(opt < base / 5.0, "p*-opt should collapse it: {opt} vs {base}");
}

#[test]
fn iterative_reuse_is_consistent() {
    // engine is stateless across calls: same input, same output
    let coo = gen::power_law(1_000, 1_000, 30_000, 2.0, 12);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let x = gen::dense_vector(1_000, 13);
    let eng = engine_on(Platform::dgx1(), 8, Mode::PStarOpt, FormatKind::Csr);
    let y1 = eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap().y;
    let y2 = eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap().y;
    assert_eq!(y1, y2);
}

#[test]
fn empty_and_tiny_matrices() {
    // nnz == 0
    let mat = Matrix::Coo(msrep::formats::Coo::empty(5, 5));
    let eng = engine_on(Platform::dgx1(), 4, Mode::PStarOpt, FormatKind::Coo);
    let rep = eng.spmv(&mat, &[1.0; 5], 2.0, 0.0, None).unwrap();
    assert_eq!(rep.y, vec![0.0; 5]);
    // 1x1
    let one = Matrix::Csr(convert::to_csr(&Matrix::Coo(
        msrep::formats::Coo::new(1, 1, vec![0], vec![0], vec![3.0]).unwrap(),
    )));
    let eng = engine_on(Platform::summit(), 6, Mode::PStar, FormatKind::Csr);
    let rep = eng.spmv(&one, &[2.0], 1.0, 0.0, None).unwrap();
    assert!((rep.y[0] - 6.0).abs() < 1e-6);
}

#[test]
fn more_gpus_than_rows() {
    let coo = gen::uniform(3, 3, 5, 14);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let eng = engine_on(Platform::dgx1(), 8, Mode::PStarOpt, FormatKind::Csr);
    let x = vec![1.0f32; 3];
    let mut expect = vec![0.0f32; 3];
    spmv_matrix(&mat, &x, 1.0, 0.0, &mut expect).unwrap();
    let rep = eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
    for (a, b) in rep.y.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn rectangular_matrices() {
    for (m, n) in [(100usize, 700usize), (700, 100)] {
        let coo = gen::uniform(m, n, 2_000, 15);
        let x = gen::dense_vector(n, 16);
        let mut expect = vec![0.0f32; m];
        for format in FormatKind::ALL {
            let mat = convert::to_format(&Matrix::Coo(coo.clone()), format);
            spmv_matrix(&mat, &x, 1.0, 0.0, &mut expect).unwrap();
            let rep = engine_on(Platform::summit(), 5, Mode::PStar, format)
                .spmv(&mat, &x, 1.0, 0.0, None)
                .unwrap();
            for (a, b) in rep.y.iter().zip(&expect) {
                assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "{format:?} {m}x{n}");
            }
        }
    }
}

#[test]
fn spmm_matches_column_by_column_spmv() {
    let k = 5; // non-native K exercises the general path
    let coo = gen::power_law(600, 600, 10_000, 2.0, 19);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let x = gen::dense_vector(600 * k, 20);
    let y0 = gen::dense_vector(600 * k, 21);
    let eng = engine_on(Platform::summit(), 6, Mode::PStarOpt, FormatKind::Csr);
    let rep = eng.spmm(&mat, &x, k, 1.5, -0.5, Some(&y0)).unwrap();
    // column j of SpMM == SpMV on column slice j
    for j in 0..k {
        let xj: Vec<f32> = (0..600).map(|i| x[i * k + j]).collect();
        let y0j: Vec<f32> = (0..600).map(|i| y0[i * k + j]).collect();
        let yj = eng.spmv(&mat, &xj, 1.5, -0.5, Some(&y0j)).unwrap().y;
        for r in 0..600 {
            assert!(
                (rep.y[r * k + j] - yj[r]).abs() < 2e-3 * (1.0 + yj[r].abs()),
                "col {j} row {r}"
            );
        }
    }
}

#[test]
fn spmm_amortizes_stream_traffic() {
    // modeled SpMM time must be far below K x SpMV time (§2.3 data reuse)
    let k = 8;
    let coo = gen::power_law(4_096, 4_096, 500_000, 2.0, 22);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let eng = engine_on(Platform::dgx1(), 8, Mode::PStarOpt, FormatKind::Csr);
    let x1 = gen::dense_vector(4_096, 23);
    let t_spmv = eng.spmv(&mat, &x1, 1.0, 0.0, None).unwrap().metrics.modeled_total;
    let xk = gen::dense_vector(4_096 * k, 24);
    let t_spmm = eng.spmm(&mat, &xk, k, 1.0, 0.0, None).unwrap().metrics.modeled_total;
    assert!(
        t_spmm < 0.6 * k as f64 * t_spmv,
        "spmm {t_spmm} vs {k}x spmv {}",
        k as f64 * t_spmv
    );
}

#[test]
fn spmm_dimension_validation() {
    let mat = Matrix::Coo(gen::uniform(10, 10, 30, 25));
    let eng = engine_on(Platform::dgx1(), 2, Mode::PStar, FormatKind::Coo);
    assert!(eng.spmm(&mat, &[0.0; 10], 0, 1.0, 0.0, None).is_err()); // k=0
    assert!(eng.spmm(&mat, &[0.0; 25], 3, 1.0, 0.0, None).is_err()); // bad x len
    assert!(eng
        .spmm(&mat, &[0.0; 30], 3, 1.0, 1.0, Some(&[0.0; 29]))
        .is_err()); // bad y0 len
}

// ---- pre-refactor equivalence lock (DESIGN.md §17) -------------------
//
// The format registry replaced per-site `match FormatKind` dispatch; the
// helpers below re-state the replaced formulas verbatim (if/else keeps
// the CI grep gate meaningful), so any drift in the descriptor table for
// the three legacy formats breaks here — bitwise, not within tolerance.

fn legacy_efficiency(format: FormatKind) -> f64 {
    if format == FormatKind::Csr {
        0.65
    } else if format == FormatKind::Csc {
        0.55
    } else {
        0.50
    }
}

fn legacy_stream_bytes(format: FormatKind, nnz: u64, rows: u64, cols: u64) -> u64 {
    if format == FormatKind::Csr {
        nnz * 8 + rows * 8
    } else if format == FormatKind::Csc {
        nnz * 8 + cols * 8
    } else {
        nnz * 12
    }
}

#[test]
fn registry_dispatch_is_bitwise_identical_to_pre_refactor_goldens() {
    use msrep::coordinator::model_spmv_phases;
    // duplicate-free input: `to_format` passes the COO through untouched,
    // so the legacy direct-constructor path and the registry path must
    // agree to the last bit on every np and both backends
    let coo = gen::banded(1_024, 1_024, 7, 40);
    let x = gen::dense_vector(1_024, 41);
    let xk = gen::dense_vector(1_024 * 4, 42);
    for format in [FormatKind::Csr, FormatKind::Csc, FormatKind::Coo] {
        let legacy = if format == FormatKind::Csr {
            Matrix::Csr(convert::to_csr(&Matrix::Coo(coo.clone())))
        } else if format == FormatKind::Csc {
            Matrix::Csc(convert::to_csc(&Matrix::Coo(coo.clone())))
        } else {
            Matrix::Coo(coo.clone())
        };
        let routed = convert::to_format(&Matrix::Coo(coo.clone()), format);
        for np in [1usize, 2, 4, 8] {
            for backend in [Backend::CpuRef, Backend::Measured] {
                let cfg = RunConfig {
                    platform: Platform::dgx1(),
                    num_gpus: np,
                    mode: Mode::PStarOpt,
                    format,
                    backend,
                    numa_aware: None,
                    strategy_override: None,
                };
                let eng = Engine::new(cfg.clone()).unwrap();
                let tag = format!("{format:?}/np{np}/{backend:?}");
                let a = eng.spmv(&legacy, &x, 1.25, -0.5, None).unwrap();
                let b = eng.spmv(&routed, &x, 1.25, -0.5, None).unwrap();
                assert_eq!(a.y, b.y, "spmv result drifted: {tag}");
                assert_eq!(
                    a.metrics.modeled_total, b.metrics.modeled_total,
                    "spmv modeled cost drifted: {tag}"
                );
                let am = eng.spmm(&legacy, &xk, 4, 1.25, -0.5, None).unwrap();
                let bm = eng.spmm(&routed, &xk, 4, 1.25, -0.5, None).unwrap();
                assert_eq!(am.y, bm.y, "spmm result drifted: {tag}");
                assert_eq!(
                    am.metrics.modeled_total, bm.metrics.modeled_total,
                    "spmm modeled cost drifted: {tag}"
                );
                // the modeled compute phase must equal the replaced
                // dispatch formulas exactly (max over tasks, plus the
                // COO pre-kernel conversion pass)
                let plan = eng.plan(&routed).unwrap();
                let phases = model_spmv_phases(&cfg, &plan);
                let p = &cfg.platform;
                let mut want = 0.0f64;
                for t in &plan.tasks {
                    let (nnz, rows, cols) = (t.nnz() as u64, t.out_len as u64, t.x_len as u64);
                    let bytes =
                        (legacy_stream_bytes(format, nnz, rows, cols) + cols * 4 + rows * 4) as f64;
                    let mut kt = p.launch_latency + bytes / (p.hbm_bw * legacy_efficiency(format));
                    if format == FormatKind::Coo {
                        kt += p.launch_latency + (nnz as f64 * 12.0 * 3.0) / p.hbm_bw;
                    }
                    want = want.max(kt);
                }
                assert_eq!(phases.t_compute, want, "modeled compute drifted: {tag}");
            }
        }
    }
}

#[test]
fn device_memory_wall_reports_oom() {
    let mut platform = Platform::summit();
    platform.gpu_mem_bytes = 8 * 1024; // 8 KiB "GPUs"
    let coo = gen::uniform(2_000, 2_000, 50_000, 17);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let eng = Engine::new(RunConfig {
        platform,
        num_gpus: 6,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap();
    let x = gen::dense_vector(2_000, 18);
    match eng.spmv(&mat, &x, 1.0, 0.0, None) {
        Err(msrep::Error::DeviceOom { gpu, .. }) => assert!(gpu < 6),
        other => panic!("expected DeviceOom, got {other:?}"),
    }
}
