//! Solver integration: the acceptance criteria end to end — CG to 1e-6 on
//! a 10k-row SPD system, plan-reuse amortization visible on the DGX-1
//! preset (planned-SpMV iteration cost < cold-partition iteration cost),
//! and the PageRank transpose (pCSC) dispatch path.

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::report::render_solver_report;
use msrep::sim::Platform;
use msrep::solver::{cg, jacobi, pagerank, PlanSource, SolverConfig};
use msrep::spmv::spmv_matrix;
use msrep::workload;

fn dgx1(np: usize) -> Engine {
    Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .expect("engine")
}

/// 10k-row certified-SPD system with a manufactured solution.
fn spd_10k() -> (Matrix, Vec<f32>, Vec<f32>) {
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(10_000, 200_000, 2.0, 7))));
    let x_star = gen::dense_vector(10_000, 8);
    let mut b = vec![0.0f32; 10_000];
    spmv_matrix(&a, &x_star, 1.0, 0.0, &mut b).unwrap();
    (a, x_star, b)
}

#[test]
fn cg_solves_10k_row_spd_system_to_1e6() {
    let (a, x_star, b) = spd_10k();
    let rep = cg(&dgx1(8), &a, &b, &SolverConfig::default()).unwrap();
    assert!(rep.converged, "residual {}", rep.final_residual);
    assert!(rep.final_residual <= 1e-6);
    assert!(rep.iterations <= 60, "iterations {}", rep.iterations);
    // the recurrence residual is honest: recompute b - A·x from scratch
    let mut ax = vec![0.0f32; 10_000];
    spmv_matrix(&a, &rep.x, 1.0, 0.0, &mut ax).unwrap();
    let b_norm: f64 = b.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    let true_res: f64 = b
        .iter()
        .zip(&ax)
        .map(|(bi, axi)| ((bi - axi) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
        / b_norm;
    assert!(true_res <= 1e-5, "recomputed residual {true_res}");
    for (i, (got, want)) in rep.x.iter().zip(&x_star).enumerate() {
        assert!((got - want).abs() < 1e-2, "x[{i}]: {got} vs {want}");
    }
}

#[test]
fn plan_reuse_amortization_visible_on_dgx1_preset() {
    let (a, _, b) = spd_10k();
    let rep = cg(&dgx1(8), &a, &b, &SolverConfig::default()).unwrap();
    // the acceptance inequality: planned-SpMV iteration cost strictly
    // below the cold re-partitioning iteration cost
    assert!(rep.t_plan > 0.0);
    assert!(
        rep.planned_iter_cost() < rep.cold_iter_cost(),
        "planned {} vs cold {}",
        rep.planned_iter_cost(),
        rep.cold_iter_cost()
    );
    assert!(rep.amortization() > 1.0);
    // and it is visible in the rendered report
    let text = render_solver_report(&rep);
    assert!(text.contains("per-iteration, planned SpMV"));
    assert!(text.contains("per-iteration, cold re-partition"));
    assert!(text.contains("plan-reuse amortization"));

    // a genuinely cold run charges what the reused run projects
    let cold_cfg = SolverConfig { plan_source: PlanSource::Cold, ..Default::default() };
    let cold = cg(&dgx1(8), &a, &b, &cold_cfg).unwrap();
    assert_eq!(cold.x, rep.x, "plan source must not change numerics");
    assert!((cold.modeled_total_s - rep.cold_total()).abs() < 1e-9);
    assert!(rep.modeled_total_s < cold.modeled_total_s);
}

#[test]
fn jacobi_agrees_with_cg_on_the_same_system() {
    let (a, _, b) = spd_10k();
    let cg_rep = cg(&dgx1(8), &a, &b, &SolverConfig::default()).unwrap();
    let j_rep = jacobi(&dgx1(8), &a, &b, &SolverConfig::default()).unwrap();
    assert!(j_rep.converged, "residual {}", j_rep.final_residual);
    for (i, (cgx, jx)) in cg_rep.x.iter().zip(&j_rep.x).enumerate() {
        assert!((cgx - jx).abs() < 1e-3, "x[{i}]: cg {cgx} vs jacobi {jx}");
    }
}

#[test]
fn pagerank_runs_through_the_transpose_plan() {
    let links = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(
        5_000, 5_000, 60_000, 2.1, 9,
    ))));
    let cfg = SolverConfig { tol: 1e-6, max_iters: 200, ..Default::default() };
    let rep = pagerank(&dgx1(8), &links, 0.85, &cfg).unwrap();
    assert!(rep.converged, "delta {}", rep.final_residual);
    let mass: f64 = rep.x.iter().map(|&v| v as f64).sum();
    assert!((mass - 1.0).abs() < 1e-3, "rank mass {mass}");
    // transpose dispatch reuses one CSC plan: amortization holds here too
    assert!(rep.planned_iter_cost() < rep.cold_iter_cost());
}

#[test]
fn poisson_scenario_from_the_workload_suite_converges() {
    let s = workload::solver_scenario_by_name("poisson2d-cg").unwrap();
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(workload::scenario_matrix(&s))));
    let u_star = vec![1.0f32; s.m];
    let mut b = vec![0.0f32; s.m];
    spmv_matrix(&a, &u_star, 1.0, 0.0, &mut b).unwrap();
    let cfg = SolverConfig { tol: s.tol, max_iters: s.max_iters, ..Default::default() };
    let rep = cg(&dgx1(8), &a, &b, &cfg).unwrap();
    assert!(rep.converged, "residual {}", rep.final_residual);
    for (i, got) in rep.x.iter().enumerate() {
        assert!((got - 1.0).abs() < 1e-2, "u[{i}] = {got}");
    }
}
