//! Autoplan integration tests: the brute-force-minimum property of the
//! tuner's ranking, end-to-end execution of auto-selected plans through
//! engine / solver / serve, and the scenario-suite routing table.

use msrep::autoplan::{plan_auto, AutoPlanOptions};
use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::util::prop::check;
use msrep::workload;

fn cfg(np: usize) -> RunConfig {
    RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    }
}

#[test]
fn auto_choice_equals_brute_force_minimum_over_candidates() {
    // property: for random matrices, the tuner's modeled cost equals the
    // brute-force minimum over the candidate set, where the brute force
    // runs every candidate plan through the REAL engine and reads the
    // executed modeled total — an independent path through the code
    check("plan_auto == brute force", 24, |g| {
        let m = g.usize_in(8..200) * 4;
        let n = g.usize_in(8..200) * 4;
        let nnz = (m * n / 50).clamp(64, 40_000);
        let seed = g.usize_in(0..1_000_000) as u64;
        let a = if g.prob(0.5) {
            Matrix::Coo(gen::power_law(m, n, nnz, 1.5 + seed as f64 % 2.0, seed))
        } else {
            Matrix::Coo(gen::uniform(m, n, nnz, seed))
        };
        let np = [1, 2, 4, 8][g.usize_in(0..4)];
        let c = cfg(np);
        let engine = Engine::new(c.clone()).unwrap();
        let reuse = [1usize, 32, 1000][g.usize_in(0..3)];
        let opts = AutoPlanOptions::for_config(&c).with_reuse(reuse);
        let auto = plan_auto(&c, &a, &opts).unwrap();

        let x = gen::dense_vector(n, seed ^ 1);
        let brute: Vec<(FormatKind, f64)> = FormatKind::ALL
            .iter()
            .map(|&f| {
                let mat = convert::to_format(&a, f);
                let plan = engine.plan(&mat).unwrap();
                let rep = engine.spmv_with_plan(&plan, &x, 1.0, 0.0, None).unwrap();
                (f, rep.metrics.modeled_total + plan.t_partition / reuse as f64)
            })
            .collect();
        let min = brute.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min);
        let auto_exec = engine.spmv_with_plan(&auto.plan, &x, 1.0, 0.0, None).unwrap();
        let auto_total =
            auto_exec.metrics.modeled_total + auto.plan.t_partition / reuse as f64;
        // the tuner's pick IS the argmin (shared pricing core, zero drift)
        assert!(
            auto_total <= min + 1e-18,
            "auto {auto_total:.6e} vs brute-force min {min:.6e} ({m}x{n}, np {np})"
        );
        // and its own predicted amortized cost matches what executed
        let predicted = auto.choice().amortized_s(reuse);
        assert!(
            (predicted - auto_total).abs() <= 1e-18,
            "predicted {predicted:.6e} vs executed {auto_total:.6e}"
        );
    });
}

#[test]
fn ranking_covers_every_brute_force_candidate_cost() {
    // each ranked row's cost must match the brute-force cost of the same
    // format exactly — not just the winner
    let c = cfg(4);
    let engine = Engine::new(c.clone()).unwrap();
    let a = Matrix::Coo(gen::power_law(300, 900, 12_000, 2.0, 5));
    let auto = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c)).unwrap();
    let x = gen::dense_vector(900, 6);
    for row in &auto.ranked {
        let mat = convert::to_format(&a, row.candidate.format);
        let plan = engine.plan(&mat).unwrap();
        let rep = engine.spmv_with_plan(&plan, &x, 1.0, 0.0, None).unwrap();
        assert_eq!(
            row.spmv_s(),
            rep.metrics.modeled_total,
            "{:?} ranked cost drifted from execution",
            row.candidate.format
        );
        assert_eq!(row.t_partition, plan.t_partition);
    }
}

#[test]
fn scenario_suite_routes_wide_to_csc_and_keeps_csr_elsewhere() {
    let c = cfg(8);
    for s in workload::autoplan_scenarios() {
        let a = Matrix::Coo(workload::autoplan_scenario_matrix(&s));
        let auto = plan_auto(&c, &a, &AutoPlanOptions::for_config(&c)).unwrap();
        let chosen = auto.choice().candidate.format;
        match s.kind {
            "short-wide" => assert_eq!(
                chosen,
                FormatKind::Csc,
                "{}: wide structures are the pCSC regime",
                s.name
            ),
            "tall-skinny" => assert_eq!(chosen, FormatKind::Csr, "{}", s.name),
            // square structural families: the pCSR default must survive
            // the tuner (it wins or ties here, never loses)
            _ => assert_eq!(chosen, FormatKind::Csr, "{}", s.name),
        }
        assert!(auto.worst_case_gain() >= 1.0, "{}", s.name);
    }
}

#[test]
fn solver_auto_source_converges_like_reused() {
    use msrep::solver::{cg, PlanSource, SolverConfig};
    let engine = Engine::new(cfg(8)).unwrap();
    // CSR input: square SPD systems are the pCSR regime, so the tuner
    // lands on the same plan Reused builds — the traces must agree exactly
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(2_000, 30_000, 2.0, 7))));
    let x_star = gen::dense_vector(2_000, 8);
    let mut b = vec![0.0f32; 2_000];
    msrep::spmv::spmv_matrix(&a, &x_star, 1.0, 0.0, &mut b).unwrap();

    let reused = cg(
        &engine,
        &a,
        &b,
        &SolverConfig { plan_source: PlanSource::Reused, ..Default::default() },
    )
    .unwrap();
    let auto = cg(
        &engine,
        &a,
        &b,
        &SolverConfig { plan_source: PlanSource::Auto, ..Default::default() },
    )
    .unwrap();
    assert!(auto.converged, "auto-planned CG must converge");
    assert_eq!(auto.plan_source, PlanSource::Auto);
    assert_eq!(auto.iterations, reused.iterations, "same math, same trace length");
    // the tuner never picks a plan whose per-iteration cost exceeds the
    // default's, and its t_plan includes the (tiny but non-zero) tune pass
    assert!(auto.planned_iter_cost() <= reused.planned_iter_cost() + 1e-18);
    assert!(auto.t_plan > 0.0);
    assert!(auto.amortization() >= 1.0);
}

#[test]
fn serve_end_to_end_with_auto_registration_hits_cache() {
    use msrep::serve::{ServeConfig, Server, SpmvRequest};
    let mut server = Server::new(ServeConfig {
        run: cfg(8),
        max_batch: 4,
        ..ServeConfig::default()
    })
    .unwrap();
    // wide tenant auto-routes to CSC; traffic must amortize through the
    // (config-aware) plan cache exactly as for manual registration
    let wide = Matrix::Coo(gen::power_law(128, 4_096, 30_000, 2.0, 9));
    let (id, auto) = server.register_auto(wide).unwrap();
    assert_eq!(auto.choice().candidate.format, FormatKind::Csc);
    let reqs: Vec<SpmvRequest> = (0..6)
        .map(|i| SpmvRequest {
            matrix: id,
            x: gen::dense_vector(4_096, 20 + i),
            alpha: 1.0,
            arrival_s: i as f64 * 1e-3,
            deadline_s: None,
        })
        .collect();
    let rep = server.run(reqs).unwrap();
    assert_eq!(rep.completed, 6);
    let stats = server.cache_stats();
    // registration seeded the tuner-built plan: no request ever rebuilds
    assert_eq!(stats.misses, 0, "the seeded plan must serve every request");
    assert_eq!(stats.hits, 6, "all traffic must hit the registration-seeded plan");
}
