//! Integration tests for the PJRT runtime: load AOT artifacts, execute,
//! and validate numerics against the rust CPU oracles.
//!
//! These tests require `make artifacts` to have run (the test harness
//! skips gracefully if the directory is absent, so `cargo test` before
//! `make artifacts` still passes — but CI/`make test` always builds
//! artifacts first).

use msrep::formats::{convert, gen, Matrix};
use msrep::runtime::{default_artifact_dir, SpmvRuntime};
use msrep::spmv::spmv_matrix;
use msrep::util::rng::Rng;

fn runtime() -> Option<SpmvRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built ({})", dir.display());
        return None;
    }
    Some(SpmvRuntime::new(dir).expect("runtime must open"))
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert!(
            (a - b).abs() <= tol * (1.0 + b.abs()),
            "{ctx}: element {i}: {a} vs {b}"
        );
    }
}

#[test]
fn spmv_partial_matches_cpu_oracle() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(42);
    let (nnz, n, m) = (3_000, 1_000, 800);
    let val: Vec<f32> = (0..nnz).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let col: Vec<u32> = (0..nnz).map(|_| rng.usize_below(n) as u32).collect();
    let row: Vec<u32> = (0..nnz).map(|_| rng.usize_below(m) as u32).collect();
    let x: Vec<f32> = (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let alpha = 1.5f32;

    let got = rt.spmv_partial(&val, &col, &row, &x, alpha, m).unwrap();

    let mut want = vec![0.0f32; m];
    for k in 0..nnz {
        want[row[k] as usize] += alpha * val[k] * x[col[k] as usize];
    }
    assert_close(&got, &want, 1e-4, "spmv_partial");
}

#[test]
fn spmv_partial_empty_stream_is_zero() {
    let Some(rt) = runtime() else { return };
    let y = rt.spmv_partial(&[], &[], &[], &[1.0, 2.0], 3.0, 5).unwrap();
    assert_eq!(y, vec![0.0; 5]);
}

#[test]
fn spmv_partial_bucket_boundaries() {
    let Some(rt) = runtime() else { return };
    // exactly at and one past the smallest nnz bucket
    for nnz in [4_096usize, 4_097] {
        let val = vec![1.0f32; nnz];
        let col = vec![0u32; nnz];
        let row = vec![0u32; nnz];
        let x = vec![2.0f32; 4];
        let y = rt.spmv_partial(&val, &col, &row, &x, 1.0, 4).unwrap();
        assert!((y[0] - 2.0 * nnz as f32).abs() < 2.0, "nnz={nnz}: {}", y[0]);
        assert_eq!(&y[1..], &[0.0, 0.0, 0.0]);
    }
}

#[test]
fn axpby_matches() {
    let Some(rt) = runtime() else { return };
    let p: Vec<f32> = (0..100).map(|i| i as f32).collect();
    let y: Vec<f32> = (0..100).map(|i| (100 - i) as f32).collect();
    let out = rt.axpby(2.0, &p, -0.5, &y).unwrap();
    for i in 0..100 {
        let want = 2.0 * p[i] - 0.5 * y[i];
        assert!((out[i] - want).abs() < 1e-4);
    }
}

#[test]
fn reduce_partials_sums_any_fan_in() {
    let Some(rt) = runtime() else { return };
    for k in [1usize, 2, 7, 8, 9, 20] {
        let parts: Vec<Vec<f32>> = (0..k).map(|i| vec![(i + 1) as f32; 50]).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let got = rt.reduce_partials(&refs, 50).unwrap();
        let want = (k * (k + 1) / 2) as f32;
        assert!(
            got.iter().all(|&v| (v - want).abs() < 1e-3),
            "k={k}: got {} want {want}",
            got[0]
        );
    }
}

#[test]
fn spmm_partial_matches_k_spmv_calls() {
    let Some(rt) = runtime() else { return };
    let k = msrep::runtime::buckets::SPMM_K;
    let mut rng = Rng::new(7);
    let (nnz, n, m) = (2_000, 500, 400);
    let val: Vec<f32> = (0..nnz).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    let col: Vec<u32> = (0..nnz).map(|_| rng.usize_below(n) as u32).collect();
    let row: Vec<u32> = (0..nnz).map(|_| rng.usize_below(m) as u32).collect();
    let x: Vec<f32> = (0..n * k).map(|_| rng.f32_range(-1.0, 1.0)).collect();

    let y = rt.spmm_partial(&val, &col, &row, &x, n, 2.0, m).unwrap();
    assert_eq!(y.len(), m * k);
    for j in 0..k {
        let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
        let yj = rt.spmv_partial(&val, &col, &row, &xj, 2.0, m).unwrap();
        for r in 0..m {
            assert!(
                (y[r * k + j] - yj[r]).abs() < 1e-3 * (1.0 + yj[r].abs()),
                "col {j} row {r}: {} vs {}",
                y[r * k + j],
                yj[r]
            );
        }
    }
}

#[test]
fn engine_spmm_pjrt_matches_cpuref() {
    let Some(_rt) = runtime() else { return };
    use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
    use msrep::sim::Platform;
    let k = msrep::runtime::buckets::SPMM_K;
    let coo = gen::power_law(800, 800, 15_000, 2.0, 88);
    let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
    let x = gen::dense_vector(800 * k, 89);
    let mk = |backend| {
        Engine::new(RunConfig {
            platform: Platform::dgx1(),
            num_gpus: 8,
            mode: Mode::PStarOpt,
            format: msrep::formats::FormatKind::Csr,
            backend,
            numa_aware: None,
            strategy_override: None,
        })
        .unwrap()
    };
    let y_pjrt = mk(Backend::Pjrt).spmm(&mat, &x, k, 1.0, 0.0, None).unwrap().y;
    let y_cpu = mk(Backend::CpuRef).spmm(&mat, &x, k, 1.0, 0.0, None).unwrap().y;
    assert_eq!(y_pjrt.len(), 800 * k);
    for (a, b) in y_pjrt.iter().zip(&y_cpu) {
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()));
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    let val = vec![1.0f32; 10];
    let col = vec![0u32; 10];
    let row = vec![0u32; 10];
    let x = vec![1.0f32; 10];
    rt.spmv_partial(&val, &col, &row, &x, 1.0, 10).unwrap();
    let after_first = rt.compile_count();
    for _ in 0..5 {
        rt.spmv_partial(&val, &col, &row, &x, 1.0, 10).unwrap();
    }
    assert_eq!(rt.compile_count(), after_first, "same bucket must not recompile");
    let stats = rt.stats();
    assert_eq!(stats.spmv_calls, 6);
    assert!(stats.padding_waste() >= 1.0);
}

#[test]
fn oversize_inputs_rejected_with_bucket_error() {
    let Some(rt) = runtime() else { return };
    let n = 2_000_000;
    let val = vec![0.0f32; n];
    let col = vec![0u32; n];
    let row = vec![0u32; n];
    match rt.spmv_partial(&val, &col, &row, &[1.0], 1.0, 1) {
        Err(msrep::Error::BucketOverflow { axis, .. }) => assert_eq!(axis, "nnz"),
        other => panic!("expected BucketOverflow, got {other:?}"),
    }
}

#[test]
fn full_engine_pjrt_backend_end_to_end() {
    let Some(rt) = runtime() else { return };
    use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
    use msrep::sim::Platform;

    let coo = gen::power_law(600, 600, 12_000, 2.0, 77);
    let x = gen::dense_vector(600, 78);
    let y0 = gen::dense_vector(600, 79);

    for format in msrep::formats::FormatKind::ALL {
        let mat = convert::to_format(&Matrix::Coo(coo.clone()), format);
        let mut expect = y0.clone();
        spmv_matrix(&mat, &x, 2.0, -1.0, &mut expect).unwrap();

        let engine = Engine::with_runtime(
            RunConfig {
                platform: Platform::summit(),
                num_gpus: 6,
                mode: Mode::PStarOpt,
                format,
                backend: Backend::Pjrt,
                numa_aware: None,
                strategy_override: None,
            },
            Some(SpmvRuntime::new(default_artifact_dir()).unwrap()),
        )
        .unwrap();
        let rep = engine.spmv(&mat, &x, 2.0, -1.0, Some(&y0)).unwrap();
        assert_close(&rep.y, &expect, 5e-3, &format!("engine/{format:?}"));
        assert!(rep.metrics.modeled_total > 0.0);
    }
    drop(rt);
}
