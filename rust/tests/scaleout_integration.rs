//! Scale-out integration: the two-tier cluster engine and the §7 traffic
//! claim end to end (DESIGN.md §16).
//!
//! Covers the four contracts the cluster layer promises:
//! * level-0 node spans are disjoint, contiguous, and conserve nnz;
//! * msrep-2level network traffic is invariant in node count while the
//!   broadcast baseline grows linearly;
//! * a one-node cluster degenerates **bitwise** to the single-node engine
//!   (same plan cost, same modeled total, same result vector);
//! * the memoized CommPlan is built once and every later solve on the
//!   same (matrix structure, topology) hits the cache.

use msrep::coordinator::{
    scaleout_spmv, Backend, ClusterEngine, Engine, Mode, NodeSplit, RunConfig, ScaleOutScheme,
};
use msrep::formats::{convert, gen, Csr, FormatKind, Matrix};
use msrep::sim::{Cluster, Platform};
use msrep::solver::{cg_cluster, SolverConfig};
use msrep::spmv::spmv_matrix;

fn node_config() -> RunConfig {
    RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 4,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    }
}

fn cluster_engine(nodes: usize) -> ClusterEngine {
    ClusterEngine::new(Cluster::of(Platform::dgx1(), nodes), node_config()).unwrap()
}

fn power_law_csr(m: usize, nnz: usize, seed: u64) -> Csr {
    convert::to_csr(&Matrix::Coo(gen::power_law(m, m, nnz, 2.0, seed)))
}

#[test]
fn node_spans_are_disjoint_and_conserve_nnz() {
    let a = power_law_csr(4_096, 120_000, 7);
    let total_nnz = a.nnz() as u64;
    for nodes in [1usize, 2, 4, 8] {
        let plan = cluster_engine(nodes).plan(&a).unwrap();
        assert_eq!(plan.node_spans.len(), nodes);
        // contiguous cover of 0..m with no overlap or gap
        let mut cursor = 0usize;
        for (i, &(start, end)) in plan.node_spans.iter().enumerate() {
            assert_eq!(start, cursor, "node {i} span starts at a gap/overlap");
            assert!(end >= start);
            cursor = end;
        }
        assert_eq!(cursor, a.rows(), "spans must cover every row");
        assert_eq!(
            plan.node_loads.iter().sum::<u64>(),
            total_nnz,
            "{nodes}-node split must conserve nnz"
        );
        // the ablation path shares the same boundary core, so its loads
        // conserve nnz too — the double-counting bug this PR fixes
        let cluster = Cluster::of(Platform::dgx1(), nodes);
        for scheme in [ScaleOutScheme::MsrepPartialMerge, ScaleOutScheme::BroadcastAllGather] {
            let rep = scaleout_spmv(&cluster, &a, scheme).unwrap();
            assert_eq!(rep.node_loads.iter().sum::<u64>(), total_nnz);
        }
    }
}

#[test]
fn msrep_network_is_flat_while_broadcast_grows_linearly() {
    let a = power_law_csr(8_192, 300_000, 11);
    let run = |nodes: usize, scheme: ScaleOutScheme| {
        scaleout_spmv(&Cluster::of(Platform::dgx1(), nodes), &a, scheme).unwrap()
    };

    // one node moves nothing over the network under either scheme
    for scheme in [ScaleOutScheme::MsrepPartialMerge, ScaleOutScheme::BroadcastAllGather] {
        let solo = run(1, scheme);
        assert_eq!(solo.t_network, 0.0);
        assert_eq!(solo.net_ingest_bytes, 0);
    }

    // msrep-2level: every node ingests the disjoint remainder of y, so
    // per-node traffic (and its ring time) is ~flat in node count
    let ms4 = run(4, ScaleOutScheme::MsrepPartialMerge);
    let ms16 = run(16, ScaleOutScheme::MsrepPartialMerge);
    assert!(ms4.t_network > 0.0 && ms16.t_network > 0.0);
    assert!(
        ms16.t_network / ms4.t_network < 1.5,
        "msrep network time should be ~invariant in node count: \
         4 nodes {} vs 16 nodes {}",
        ms4.t_network,
        ms16.t_network
    );
    assert!(
        (ms16.net_ingest_bytes as f64) < 1.5 * ms4.net_ingest_bytes as f64,
        "msrep per-node ingest should stay flat: {} vs {}",
        ms4.net_ingest_bytes,
        ms16.net_ingest_bytes
    );

    // broadcast [39]: every node ingests (N-1) full copies of y — linear
    let bc4 = run(4, ScaleOutScheme::BroadcastAllGather);
    let bc16 = run(16, ScaleOutScheme::BroadcastAllGather);
    assert!(
        bc16.net_ingest_bytes > 3 * bc4.net_ingest_bytes,
        "broadcast ingest should grow ~linearly: {} vs {}",
        bc4.net_ingest_bytes,
        bc16.net_ingest_bytes
    );
    assert!(bc16.t_network > 3.0 * bc4.t_network);
    // and at any fixed node count broadcast pays more than msrep
    assert!(bc4.net_ingest_bytes > ms4.net_ingest_bytes);
}

#[test]
fn one_node_cluster_is_bitwise_identical_to_the_engine() {
    let a = power_law_csr(3_000, 60_000, 13);
    let x = gen::dense_vector(a.cols(), 5);

    let ce = cluster_engine(1);
    let cplan = ce.plan(&a).unwrap();
    let crep = ce.spmv_with_plan(&cplan, &x, 1.0, 0.0, None).unwrap();

    let engine = Engine::new(node_config()).unwrap();
    let m = Matrix::Csr(a.clone());
    let eplan = engine.plan(&m).unwrap();
    let erep = engine.spmv_with_plan(&eplan, &x, 1.0, 0.0, None).unwrap();

    // degenerate cluster charges nothing: no level-0 scan, no comm build,
    // zero-step exchange — every modeled number is bitwise the engine's
    assert_eq!(cplan.t_partition, eplan.t_partition);
    assert_eq!(cplan.comm.t_build, 0.0);
    assert_eq!(cplan.comm.t_exchange, 0.0);
    assert_eq!(cplan.comm.t_allreduce_scalar, 0.0);
    assert_eq!(crep.t_network, 0.0);
    assert_eq!(crep.modeled_total, erep.metrics.modeled_total);
    assert_eq!(crep.y, erep.y, "one-node cluster result must be bitwise identical");

    // and the numerics are the reference kernel's
    let mut want = vec![0.0f32; a.rows()];
    spmv_matrix(&m, &x, 1.0, 0.0, &mut want).unwrap();
    assert_eq!(crep.y, want);
}

#[test]
fn second_solve_hits_the_memoized_comm_plan() {
    // SPD system so CG is well-posed; convergence is irrelevant here
    let n = 600;
    let a = Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::spd(n, 8_000, 2.0, 17))));
    let mut b = vec![0.0f32; n];
    spmv_matrix(&a, &gen::dense_vector(n, 18), 1.0, 0.0, &mut b).unwrap();
    let cfg = SolverConfig { max_iters: 5, ..Default::default() };

    let ce = cluster_engine(4);
    let first = cg_cluster(&ce, &a, &b, &cfg).unwrap();
    let after_first = ce.comm_stats();
    assert_eq!(after_first.misses, 1, "first solve builds the CommPlan once");

    let second = cg_cluster(&ce, &a, &b, &cfg).unwrap();
    let after_second = ce.comm_stats();
    assert_eq!(after_second.misses, 1, "second solve must not rebuild");
    assert!(after_second.hits >= 1, "stats {after_second:?}");

    // the cache hit is visible in the plan charge: the second solve skips
    // the schedule build but still pays the two-tier partitioning
    assert!(second.t_plan < first.t_plan, "{} vs {}", second.t_plan, first.t_plan);
    assert!(second.t_plan > 0.0);
    // identical numerics either way
    assert_eq!(first.x, second.x);
}

#[test]
fn topology_aware_split_beats_nnz_balance_on_power_law() {
    let a = power_law_csr(8_192, 400_000, 23);
    let mut boundaries_shifted = false;
    for nodes in [4usize, 8] {
        let ce = cluster_engine(nodes);
        let aware = ce.plan_with_split(&a, NodeSplit::TopologyAware).unwrap();
        let blind = ce.plan_with_split(&a, NodeSplit::NnzBalanced).unwrap();
        let t_aware = ce.model_spmv(&aware).unwrap().t_intra;
        let t_blind = ce.model_spmv(&blind).unwrap().t_intra;
        assert!(
            t_aware <= t_blind,
            "{nodes} nodes: topology-aware {t_aware} must not lose to nnz-balance {t_blind}"
        );
        boundaries_shifted |= aware.node_spans != blind.node_spans;
    }
    // the per-row cost term must actually shift level-0 boundaries somewhere
    // in the sweep — otherwise "topology-aware" is a no-op relabeling
    assert!(boundaries_shifted, "aware and blind splits never diverged");
}
