//! Determinism regression tests — the seeded-RNG contract the benches and
//! EXPERIMENTS-style reports rely on: the same seed must produce
//! byte-identical generated matrices, and a full bench-style run
//! serialized to JSON must be identical across two executions (modeled
//! numbers only — host wall measurements are honest and therefore
//! excluded from the contract).

use std::collections::BTreeMap;

use msrep::coordinator::{Backend, Engine, Mode, RunConfig};
use msrep::formats::{convert, gen, Coo, FormatKind, Matrix};
use msrep::sim::Platform;
use msrep::util::json::Value;

/// Byte-level equality of two generated COO matrices (f32 bit patterns,
/// not approximate comparison — the contract is *identical*, not close).
fn assert_identical(a: &Coo, b: &Coo, what: &str) {
    assert_eq!(a.rows(), b.rows(), "{what}: rows");
    assert_eq!(a.cols(), b.cols(), "{what}: cols");
    assert_eq!(a.row_idx, b.row_idx, "{what}: row_idx");
    assert_eq!(a.col_idx, b.col_idx, "{what}: col_idx");
    let av: Vec<u32> = a.val.iter().map(|v| v.to_bits()).collect();
    let bv: Vec<u32> = b.val.iter().map(|v| v.to_bits()).collect();
    assert_eq!(av, bv, "{what}: val bits");
}

#[test]
fn every_generator_is_byte_identical_across_runs() {
    for seed in [1u64, 42, 0xDEAD] {
        assert_identical(
            &gen::power_law(400, 300, 5_000, 1.7, seed),
            &gen::power_law(400, 300, 5_000, 1.7, seed),
            "power_law",
        );
        assert_identical(
            &gen::uniform(200, 200, 3_000, seed),
            &gen::uniform(200, 200, 3_000, seed),
            "uniform",
        );
        assert_identical(
            &gen::banded(150, 150, 7, seed),
            &gen::banded(150, 150, 7, seed),
            "banded",
        );
        assert_identical(
            &gen::two_band(100, 100, 2_000, 6.0, seed),
            &gen::two_band(100, 100, 2_000, 6.0, seed),
            "two_band",
        );
        assert_identical(&gen::spd(120, 1_500, 2.0, seed), &gen::spd(120, 1_500, 2.0, seed), "spd");
        assert_identical(
            &gen::block_diagonal(160, 8, 2_000, seed),
            &gen::block_diagonal(160, 8, 2_000, seed),
            "block_diagonal",
        );
        let va = gen::dense_vector(500, seed);
        let vb = gen::dense_vector(500, seed);
        assert_eq!(
            va.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            vb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "dense_vector bits"
        );
        // and a different seed really changes the stream
        assert_ne!(
            gen::uniform(200, 200, 3_000, seed).val,
            gen::uniform(200, 200, 3_000, seed + 1).val
        );
    }
    // the structural (seedless) generators are trivially repeatable
    assert_identical(&gen::laplacian_2d(12), &gen::laplacian_2d(12), "laplacian_2d");
    assert_identical(&gen::aggregation_2d(9), &gen::aggregation_2d(9), "aggregation_2d");
    assert_identical(&gen::identity(33), &gen::identity(33), "identity");
}

/// One bench-style sweep serialized to JSON: generated workloads, plans
/// and modeled engine numbers — everything a bench prints except the
/// host wall-clock measurements.
fn bench_json(seed: u64) -> String {
    let eng = Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap();
    let mut runs = Vec::new();
    for (name, coo) in [
        ("power-law", gen::power_law(600, 600, 9_000, 1.8, seed)),
        ("two-band", gen::two_band(500, 500, 8_000, 8.0, seed)),
    ] {
        let mat = Matrix::Csr(convert::to_csr(&Matrix::Coo(coo)));
        let x = gen::dense_vector(mat.cols(), seed + 1);
        let rep = eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
        let mut checksum = 0.0f64;
        for v in &rep.y {
            checksum += *v as f64;
        }
        let mut obj = BTreeMap::new();
        obj.insert("name".to_string(), Value::Str(name.to_string()));
        obj.insert("nnz".to_string(), Value::Num(mat.nnz() as f64));
        obj.insert("imbalance".to_string(), Value::Num(rep.metrics.imbalance));
        obj.insert("modeled_total".to_string(), Value::Num(rep.metrics.modeled_total));
        obj.insert("h2d_bytes".to_string(), Value::Num(rep.metrics.h2d_bytes as f64));
        obj.insert("y_checksum".to_string(), Value::Num(checksum));
        obj.insert(
            "loads".to_string(),
            Value::Arr(rep.metrics.loads.iter().map(|&l| Value::Num(l as f64)).collect()),
        );
        runs.push(Value::Obj(obj));
    }
    let mut root = BTreeMap::new();
    root.insert("seed".to_string(), Value::Num(seed as f64));
    root.insert("runs".to_string(), Value::Arr(runs));
    Value::Obj(root).to_json()
}

#[test]
fn bench_json_is_identical_across_two_runs() {
    let first = bench_json(42);
    let second = bench_json(42);
    assert_eq!(first, second, "two runs of the same seeded bench diverged");
    // sanity: the serialization actually carries the numbers
    assert!(first.contains("modeled_total"));
    assert!(first.contains("power-law"));
    // a different seed produces a different document
    assert_ne!(first, bench_json(43));
}

#[test]
fn workload_scenario_factories_are_deterministic() {
    // the scenario sets the benches iterate must regenerate identically
    for s in msrep::workload::solver_scenarios() {
        assert_identical(
            &msrep::workload::scenario_matrix(&s),
            &msrep::workload::scenario_matrix(&s),
            s.name,
        );
    }
    for s in msrep::workload::sptrsv_scenarios() {
        let a = msrep::workload::sptrsv_scenario_factor(&s);
        let b = msrep::workload::sptrsv_scenario_factor(&s);
        assert_eq!(a.row_ptr, b.row_ptr, "{}", s.name);
        assert_eq!(a.col_idx, b.col_idx, "{}", s.name);
        assert_eq!(
            a.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "{}",
            s.name
        );
    }
    for s in msrep::workload::autoplan_scenarios() {
        assert_identical(
            &msrep::workload::autoplan_scenario_matrix(&s),
            &msrep::workload::autoplan_scenario_matrix(&s),
            s.name,
        );
    }
}

/// One measured-backend run: the y vector's exact bit pattern plus the
/// modeled metrics the contract covers (wall-clock fields excluded —
/// those are honest measurements and may differ run to run).
fn measured_run(mode: Mode, fmt: FormatKind, np: usize) -> (Vec<u32>, u64, u64) {
    let eng = Engine::new(RunConfig {
        platform: Platform::dgx1(),
        num_gpus: np,
        mode,
        format: fmt,
        backend: Backend::Measured,
        numa_aware: None,
        strategy_override: None,
    })
    .unwrap();
    let mat = convert::to_format(&Matrix::Coo(gen::power_law(500, 500, 7_000, 1.8, 77)), fmt);
    let x = gen::dense_vector(500, 78);
    let rep = eng.spmv(&mat, &x, 1.1, 0.3, Some(&gen::dense_vector(500, 79))).unwrap();
    assert_eq!(rep.metrics.measured_busy.len(), np);
    let bits = rep.y.iter().map(|v| v.to_bits()).collect();
    (bits, rep.metrics.modeled_total.to_bits(), rep.metrics.t_merge.to_bits())
}

#[test]
fn measured_backend_is_byte_identical_across_runs() {
    // thread scheduling must never leak into numerics: the worker fan-out
    // collects partials in GPU order, so two executions — whatever order
    // the OS ran the threads in — produce the same bytes
    for fmt in FormatKind::ALL {
        for np in [1usize, 4, 8] {
            let a = measured_run(Mode::PStarOpt, fmt, np);
            let b = measured_run(Mode::PStarOpt, fmt, np);
            assert_eq!(a, b, "{} np{np}: measured run diverged across executions", fmt.name());
        }
    }
}

#[test]
fn measured_backend_is_schedule_independent() {
    // Baseline runs the kernels serially on the driver thread; p* fans
    // them out one thread per GPU. Same partitions (strategy pinned to
    // the baseline's blocks split), same merge order — the y bytes must
    // not depend on which schedule executed them.
    for fmt in FormatKind::ALL {
        let run = |mode: Mode| {
            let eng = Engine::new(RunConfig {
                platform: Platform::dgx1(),
                num_gpus: 8,
                mode,
                format: fmt,
                backend: Backend::Measured,
                numa_aware: None,
                strategy_override: Some(msrep::coordinator::partitioner::Strategy::NnzBalanced),
            })
            .unwrap();
            let mat =
                convert::to_format(&Matrix::Coo(gen::power_law(400, 400, 6_000, 1.9, 88)), fmt);
            let x = gen::dense_vector(400, 89);
            let rep = eng.spmv(&mat, &x, 1.0, 0.0, None).unwrap();
            rep.y.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
        };
        let serial = run(Mode::Baseline);
        let threaded = run(Mode::PStar);
        assert_eq!(serial, threaded, "{}: serial vs threaded schedule diverged", fmt.name());
    }
}

/// The determinism half of the registry equivalence lock (DESIGN.md §17):
/// for the three legacy formats, a duplicate-free matrix routed through
/// the registry's `convert_into` hook must produce byte-identical plans,
/// modeled phase costs, and SpMV/SpMM numerics to one built with the
/// direct per-format constructors — across np and both real backends.
#[test]
fn registry_routing_is_byte_identical_to_direct_construction() {
    let coo = gen::banded(600, 600, 5, 91);
    let x = gen::dense_vector(600, 92);
    let xk = gen::dense_vector(600 * 3, 93);
    for fmt in [FormatKind::Csr, FormatKind::Csc, FormatKind::Coo] {
        let direct = if fmt == FormatKind::Csr {
            Matrix::Csr(convert::to_csr(&Matrix::Coo(coo.clone())))
        } else if fmt == FormatKind::Csc {
            Matrix::Csc(convert::to_csc(&Matrix::Coo(coo.clone())))
        } else {
            Matrix::Coo(coo.clone())
        };
        let routed = convert::to_format(&Matrix::Coo(coo.clone()), fmt);
        for np in [1usize, 2, 4, 8] {
            for backend in [Backend::CpuRef, Backend::Measured] {
                let eng = Engine::new(RunConfig {
                    platform: Platform::dgx1(),
                    num_gpus: np,
                    mode: Mode::PStarOpt,
                    format: fmt,
                    backend,
                    numa_aware: None,
                    strategy_override: None,
                })
                .unwrap();
                let ctx = format!("{} np{np} {backend:?}", fmt.name());

                let pa = eng.plan(&direct).unwrap();
                let pb = eng.plan(&routed).unwrap();
                assert_eq!(pa.work_loads, pb.work_loads, "{ctx}: plan loads");
                assert_eq!(
                    pa.t_partition.to_bits(),
                    pb.t_partition.to_bits(),
                    "{ctx}: modeled partition cost"
                );
                for (ta, tb) in pa.tasks.iter().zip(&pb.tasks) {
                    assert_eq!(ta.padded, tb.padded, "{ctx}: task padding");
                    assert_eq!(ta.col_idx, tb.col_idx, "{ctx}: task col_idx");
                    assert_eq!(ta.row_idx, tb.row_idx, "{ctx}: task row_idx");
                    assert_eq!(
                        ta.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        tb.val.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{ctx}: task payload bits"
                    );
                }

                let a = eng.spmv(&direct, &x, 1.0, 0.0, None).unwrap();
                let b = eng.spmv(&routed, &x, 1.0, 0.0, None).unwrap();
                assert_eq!(
                    a.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{ctx}: spmv y bits"
                );
                assert_eq!(
                    a.metrics.modeled_total.to_bits(),
                    b.metrics.modeled_total.to_bits(),
                    "{ctx}: spmv modeled total"
                );
                assert_eq!(
                    a.metrics.t_compute.to_bits(),
                    b.metrics.t_compute.to_bits(),
                    "{ctx}: spmv compute phase"
                );

                let am = eng.spmm(&direct, &xk, 3, 1.0, 0.0, None).unwrap();
                let bm = eng.spmm(&routed, &xk, 3, 1.0, 0.0, None).unwrap();
                assert_eq!(
                    am.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    bm.y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{ctx}: spmm y bits"
                );
                assert_eq!(
                    am.metrics.modeled_total.to_bits(),
                    bm.metrics.modeled_total.to_bits(),
                    "{ctx}: spmm modeled total"
                );
            }
        }
    }
}

#[test]
fn auto_selection_is_deterministic_across_runs() {
    // the tuner's whole verdict — winner, ranking order, and every
    // modeled number — must be bit-identical across two runs on the same
    // input (HashMap iteration order or wall-clock noise must not leak
    // into the decision)
    let cfg = RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    };
    for s in msrep::workload::autoplan_scenarios() {
        let a = Matrix::Coo(msrep::workload::autoplan_scenario_matrix(&s));
        let opts = msrep::autoplan::AutoPlanOptions::for_config(&cfg);
        let first = msrep::autoplan::plan_auto(&cfg, &a, &opts).unwrap();
        let second = msrep::autoplan::plan_auto(&cfg, &a, &opts).unwrap();
        assert_eq!(
            first.choice().candidate,
            second.choice().candidate,
            "{}: winner changed between runs",
            s.name
        );
        assert_eq!(first.ranked.len(), second.ranked.len(), "{}", s.name);
        for (x, y) in first.ranked.iter().zip(&second.ranked) {
            assert_eq!(x.candidate, y.candidate, "{}: ranking order changed", s.name);
            assert_eq!(
                x.spmv_s().to_bits(),
                y.spmv_s().to_bits(),
                "{}: modeled replay cost drifted",
                s.name
            );
            assert_eq!(
                x.t_partition.to_bits(),
                y.t_partition.to_bits(),
                "{}: modeled build cost drifted",
                s.name
            );
        }
        assert_eq!(first.t_tune.to_bits(), second.t_tune.to_bits(), "{}", s.name);
    }
}
