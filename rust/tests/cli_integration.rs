//! CLI integration tests: drive the compiled `msrep` binary end to end.

use std::path::PathBuf;
use std::process::{Command, Output};

fn msrep(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_msrep"))
        .args(args)
        .output()
        .expect("binary must run")
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).to_string()
}

fn tmpdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("msrep_cli_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn no_args_prints_usage() {
    let o = msrep(&[]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("commands:"));
    assert!(s.contains("serve-bench"), "usage must list serve-bench");
    assert!(s.contains("solver-bench"), "usage must list solver-bench");
}

#[test]
fn solver_bench_reports_amortization() {
    let o = msrep(&[
        "solver-bench",
        "--method",
        "cg",
        "--m",
        "2000",
        "--nnz",
        "30000",
        "--max-iters",
        "100",
    ]);
    assert!(
        o.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&o.stderr)
    );
    let s = stdout(&o);
    assert!(s.contains("per-iteration, planned SpMV"), "missing planned cost:\n{s}");
    assert!(s.contains("per-iteration, cold re-partition"), "missing cold cost:\n{s}");
    assert!(s.contains("plan-reuse amortization"), "missing amortization:\n{s}");
    assert!(
        s.contains("plan reuse: planned-SpMV iteration cost"),
        "missing summary line:\n{s}"
    );
    assert!(s.contains("yes"), "CG must converge in the summary:\n{s}");
}

#[test]
fn solver_bench_help_and_bad_flags() {
    let o = msrep(&["solver-bench", "--help"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("--dominance") && s.contains("--source"));
    let o = msrep(&["solver-bench", "--method", "frobnicate"]);
    assert!(!o.status.success());
    let o = msrep(&["solver-bench", "--dominance", "0.5"]);
    assert!(!o.status.success());
}

#[test]
fn unknown_command_fails_with_hint() {
    let o = msrep(&["frobnicate"]);
    assert!(!o.status.success());
    assert!(String::from_utf8_lossy(&o.stderr).contains("unknown command"));
}

#[test]
fn info_lists_platforms() {
    let o = msrep(&["info"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("summit") && s.contains("dgx1"));
}

#[test]
fn suite_lists_six_matrices() {
    let o = msrep(&["suite"]);
    assert!(o.status.success());
    let s = stdout(&o);
    for name in ["mouse_gene", "wb-edu", "HV15R"] {
        assert!(s.contains(name), "missing {name}");
    }
}

#[test]
fn gen_profile_partition_run_pipeline() {
    let dir = tmpdir();
    let mtx = dir.join("cli_test.mtx");
    let mtx_s = mtx.to_str().unwrap();

    // gen
    let o = msrep(&[
        "gen", "--out", mtx_s, "--kind", "power-law", "--m", "500", "--nnz", "5000",
        "--r", "2.0", "--seed", "1",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    assert!(mtx.exists());

    // profile
    let o = msrep(&["profile", "--matrix", mtx_s]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("power-law R"));

    // partition (balanced vs blocks imbalance should differ)
    let o = msrep(&["partition", "--matrix", mtx_s, "--np", "4", "--strategy", "balanced"]);
    assert!(o.status.success());
    assert!(stdout(&o).contains("imbalance"));

    // run on the CPU backend with verification
    let o = msrep(&[
        "run", "--matrix", mtx_s, "--platform", "summit", "--gpus", "6", "--mode",
        "popt", "--backend", "cpu", "--verify",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("TOTAL") && s.contains("max relative error"));

    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn run_on_suite_matrix_baseline_mode() {
    let o = msrep(&[
        "run", "--suite", "mouse_gene", "--platform", "dgx1", "--mode", "baseline",
        "--backend", "cpu", "--format", "coo",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    assert!(stdout(&o).contains("mode=baseline"));
}

#[test]
fn serve_bench_reports_batching_and_cache() {
    let o = msrep(&[
        "serve-bench", "--tenants", "2", "--requests", "24", "--m", "512", "--nnz",
        "8000", "--batch", "4", "--rate", "1000000", "--compare",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("plan-cache hit rate"), "missing cache stats:\n{s}");
    assert!(s.contains("batch-size histogram"), "missing histogram:\n{s}");
    assert!(s.contains("speedup over sequential"), "missing comparison:\n{s}");
}

#[test]
fn serve_bench_help_lists_flags() {
    let o = msrep(&["serve-bench", "--help"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("--batch") && s.contains("--flush-us") && s.contains("--engines"));
}

#[test]
fn spgemm_bench_compares_planning_models() {
    let o = msrep(&["spgemm-bench", "--scenario", "galerkin-rap", "--gpus", "4"]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("galerkin-rap"), "missing scenario header:\n{s}");
    assert!(s.contains("symbolic"), "missing phase split:\n{s}");
    assert!(s.contains("compression nnz(C)/flops"), "missing compression:\n{s}");
    assert!(
        s.contains("nnz-balanced vs flop-balanced planning"),
        "missing comparison summary:\n{s}"
    );
}

#[test]
fn sptrsv_bench_compares_wavefront_splits() {
    let o = msrep(&["sptrsv-bench", "--scenario", "powerlaw-lower", "--gpus", "4"]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("powerlaw-lower"), "missing scenario header:\n{s}");
    assert!(s.contains("levels (critical path)"), "missing structure table:\n{s}");
    assert!(s.contains("parallelism histogram"), "missing histogram:\n{s}");
    assert!(
        s.contains("verify: max relative error vs sequential oracle"),
        "missing verification line:\n{s}"
    );
    assert!(
        s.contains("level-balanced vs naive row-block wavefront split"),
        "missing comparison summary:\n{s}"
    );
}

#[test]
fn sptrsv_bench_help_and_bad_scenario() {
    let o = msrep(&["sptrsv-bench", "--help"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("--scenario") && s.contains("--no-compare") && s.contains("--upper"));
    assert!(!msrep(&["sptrsv-bench", "--scenario", "frobnicate"]).status.success());
}

#[test]
fn solver_bench_runs_pcg_with_ilu0() {
    let o = msrep(&["solver-bench", "--method", "pcg", "--m", "1024", "--max-iters", "400"]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("== pcg:"), "missing pcg header:\n{s}");
    assert!(s.contains("plan-reuse amortization"), "missing amortization:\n{s}");
    assert!(s.contains("yes"), "PCG must converge in the summary:\n{s}");
}

#[test]
fn spgemm_bench_help_and_bad_scenario() {
    let o = msrep(&["spgemm-bench", "--help"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("--scenario") && s.contains("--no-compare"));
    assert!(!msrep(&["spgemm-bench", "--scenario", "frobnicate"]).status.success());
}

#[test]
fn profile_prints_spgemm_flop_histogram() {
    let dir = tmpdir();
    let mtx = dir.join("cli_spgemm_profile.mtx");
    let mtx_s = mtx.to_str().unwrap();
    let o = msrep(&[
        "gen", "--out", mtx_s, "--kind", "power-law", "--m", "400", "--nnz", "4000",
        "--r", "1.8", "--seed", "2",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let o = msrep(&["profile", "--matrix", mtx_s]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("per-row SpGEMM flop histogram"), "missing histogram:\n{s}");
    assert!(s.contains("row-flop imbalance"), "missing imbalance line:\n{s}");
    // opt-out flag suppresses it
    let o = msrep(&["profile", "--matrix", mtx_s, "--no-spgemm"]);
    assert!(o.status.success());
    assert!(!stdout(&o).contains("flop histogram"));
    // rectangular matrices skip the A·A preview instead of panicking
    let rect = dir.join("cli_spgemm_profile_rect.mtx");
    let rect_s = rect.to_str().unwrap();
    let o = msrep(&[
        "gen", "--out", rect_s, "--kind", "uniform", "--m", "100", "--n", "250", "--nnz",
        "1000", "--seed", "3",
    ]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let o = msrep(&["profile", "--matrix", rect_s]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("histogram skipped"), "missing skip note:\n{s}");
    std::fs::remove_file(mtx).ok();
    std::fs::remove_file(rect).ok();
}

#[test]
fn bad_flags_are_rejected() {
    assert!(!msrep(&["run", "--platform", "cray"]).status.success());
    assert!(!msrep(&["run", "--suite", "nope", "--backend", "cpu"]).status.success());
    assert!(!msrep(&["gen", "--m", "abc"]).status.success());
    assert!(!msrep(&["partition", "--np", "4"]).status.success()); // no matrix
}

#[test]
fn autoplan_bench_routes_and_passes_acceptance() {
    // one wide scenario: the tuner must pick pCSC and pass the
    // never-worse-than-worst acceptance gate
    let o = msrep(&["autoplan-bench", "--scenario", "short-wide"]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("== short-wide =="), "missing scenario header:\n{s}");
    assert!(s.contains("<- chosen"), "missing choice marker:\n{s}");
    assert!(s.contains("csc/balanced/np8"), "wide must route to pCSC:\n{s}");
    assert!(s.contains("vs median"), "missing comparison column:\n{s}");
    assert!(s.contains("tuner vs median fixed format"), "missing aggregate line:\n{s}");
}

#[test]
fn autoplan_bench_help_full_sweep_and_bad_scenario() {
    let o = msrep(&["autoplan-bench", "--help"]);
    assert!(o.status.success());
    let s = stdout(&o);
    assert!(s.contains("--scenario") && s.contains("--reuse") && s.contains("--full"));
    assert!(!msrep(&["autoplan-bench", "--scenario", "frobnicate"]).status.success());
    // the full sweep enumerates strategies and GPU counts
    let o = msrep(&["autoplan-bench", "--scenario", "banded-stencil", "--full", "--gpus", "4"]);
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let s = stdout(&o);
    assert!(s.contains("full sweep"), "missing sweep header:\n{s}");
    assert!(s.contains("/blocks/"), "sweep must price the blocks strategy:\n{s}");
    assert!(s.contains("np1") && s.contains("np4"), "sweep must price GPU counts:\n{s}");
}
