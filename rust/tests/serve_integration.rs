//! Integration tests for the serving layer: batched numerics vs the CPU
//! oracle, the >= 2x batched-throughput acceptance bar, plan-cache
//! amortization on repeated-matrix traffic, backpressure, and deadlines.

use msrep::coordinator::{Backend, Mode, RunConfig};
use msrep::formats::{convert, gen, FormatKind, Matrix};
use msrep::serve::{
    fingerprint, MatrixId, Outcome, RejectReason, ServeConfig, Server, SpmvRequest,
};
use msrep::sim::Platform;
use msrep::spmv::spmv_matrix;

fn run_config() -> RunConfig {
    RunConfig {
        platform: Platform::dgx1(),
        num_gpus: 8,
        mode: Mode::PStarOpt,
        format: FormatKind::Csr,
        backend: Backend::CpuRef,
        numa_aware: None,
        strategy_override: None,
    }
}

fn serve_config(max_batch: usize, cache: usize) -> ServeConfig {
    ServeConfig {
        run: run_config(),
        num_engines: 1,
        max_batch,
        flush_deadline_s: 50e-6,
        queue_capacity: 1024,
        plan_cache_capacity: cache,
        cluster: None,
    }
}

fn csr_matrix(m: usize, nnz: usize, seed: u64) -> Matrix {
    Matrix::Csr(convert::to_csr(&Matrix::Coo(gen::power_law(m, m, nnz, 2.0, seed))))
}

fn burst(id: MatrixId, n: usize, count: usize, seed0: u64) -> Vec<SpmvRequest> {
    (0..count)
        .map(|i| SpmvRequest {
            matrix: id,
            x: gen::dense_vector(n, seed0 + i as u64),
            alpha: 1.0 + (i % 3) as f32 * 0.5,
            arrival_s: 0.0,
            deadline_s: None,
        })
        .collect()
}

#[test]
fn batched_results_match_cpu_oracle() {
    let mut server = Server::new(serve_config(8, 8)).unwrap();
    let mat_a = csr_matrix(512, 8_000, 1);
    let mat_b = csr_matrix(512, 8_000, 2);
    let ida = server.register(mat_a.clone());
    let idb = server.register(mat_b.clone());

    let mut trace = burst(ida, 512, 12, 100);
    trace.extend(burst(idb, 512, 12, 200));
    let inputs: Vec<(MatrixId, Vec<f32>, f32)> = trace
        .iter()
        .map(|r| (r.matrix, r.x.clone(), r.alpha))
        .collect();

    let report = server.run(trace).unwrap();
    assert_eq!(report.completed, 24);
    assert_eq!(report.rejected + report.expired, 0);

    for (i, (mid, x, alpha)) in inputs.iter().enumerate() {
        let mat = if *mid == ida { &mat_a } else { &mat_b };
        let mut expect = vec![0.0f32; 512];
        spmv_matrix(mat, x, *alpha, 0.0, &mut expect).unwrap();
        match &report.outcomes[i] {
            Outcome::Completed { y, batch_k, .. } => {
                assert!(*batch_k >= 1 && *batch_k <= 8);
                for (a, b) in y.iter().zip(&expect) {
                    assert!(
                        (a - b).abs() < 3e-3 * (1.0 + b.abs()),
                        "request {i}: {a} vs {b}"
                    );
                }
            }
            other => panic!("request {i}: expected Completed, got {other:?}"),
        }
    }
}

#[test]
fn batched_throughput_at_least_2x_sequential() {
    // ISSUE-1 acceptance: batched SpMM path >= 2x modeled throughput over
    // sequential per-request SpMV at batch >= 8 on Platform::dgx1(), with
    // a plan-cache hit rate > 0 on repeated-matrix traffic.
    let run = |cfg: ServeConfig| {
        let mut server = Server::new(cfg).unwrap();
        let id = server.register(csr_matrix(4_096, 200_000, 3));
        let trace = burst(id, 4_096, 64, 300);
        server.run(trace).unwrap()
    };
    let batched = run(serve_config(8, 8));
    let sequential = run(serve_config(8, 8).sequential_baseline());

    assert_eq!(batched.completed, 64);
    assert_eq!(sequential.completed, 64);
    assert!(batched.mean_batch() > 4.0, "batching must engage: {}", batched.mean_batch());
    assert_eq!(sequential.mean_batch(), 1.0);

    let speedup = batched.throughput_rps() / sequential.throughput_rps();
    assert!(
        speedup >= 2.0,
        "batched {} req/s vs sequential {} req/s = {speedup:.2}x (need >= 2x)",
        batched.throughput_rps(),
        sequential.throughput_rps()
    );
    assert!(
        batched.cache.hit_rate() > 0.0,
        "repeat-matrix traffic must hit the plan cache"
    );
    assert_eq!(sequential.cache.hit_rate(), 0.0, "baseline must not cache");
}

#[test]
fn plan_cache_amortizes_repeated_matrix_traffic() {
    let mut server = Server::new(serve_config(4, 8)).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 4));
    let report = server.run(burst(id, 512, 32, 400)).unwrap();
    // 32 requests at batch 4 = 8 dispatches: 1 plan build + 7 hits
    assert_eq!(report.batch_sizes.len(), 8);
    assert_eq!(report.cache.misses, 1);
    assert_eq!(report.cache.hits, 7);
    assert!((report.cache.hit_rate() - 7.0 / 8.0).abs() < 1e-12);
}

#[test]
fn identical_tenant_matrices_share_one_plan() {
    // two tenants registering a numerically identical matrix share a
    // single cached plan; same structure with different values must NOT
    // (cached plans embed the value streams)
    let mat = csr_matrix(512, 8_000, 5);
    assert_eq!(fingerprint(&mat), fingerprint(&mat.clone()));
    if let Matrix::Csr(c) = &mat {
        let mut scaled = c.clone();
        for v in &mut scaled.val {
            *v *= 3.0;
        }
        assert_ne!(fingerprint(&mat), fingerprint(&Matrix::Csr(scaled)));
    }
    let mut server = Server::new(serve_config(4, 8)).unwrap();
    let ida = server.register(mat.clone());
    let idb = server.register(mat);
    let mut trace = burst(ida, 512, 4, 500);
    trace.extend(burst(idb, 512, 4, 600));
    let report = server.run(trace).unwrap();
    assert_eq!(report.completed, 8);
    assert_eq!(report.cache.misses, 1, "tenant B must reuse tenant A's plan");
    assert!(report.cache.hits >= 1);
}

#[test]
fn backpressure_rejects_past_queue_capacity() {
    // max_batch > queue_capacity: a burst can never fill a batch, so the
    // window only drains on the flush deadline — everything past the
    // capacity is shed at admission.
    let cfg = ServeConfig {
        queue_capacity: 8,
        max_batch: 16,
        ..serve_config(16, 8)
    };
    let mut server = Server::new(cfg).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 6));
    let report = server.run(burst(id, 512, 40, 700)).unwrap();
    assert_eq!(report.completed, 8);
    assert_eq!(report.rejected, 32);
    let queue_full = report
        .outcomes
        .iter()
        .filter(|o| matches!(o, Outcome::Rejected(RejectReason::QueueFull)))
        .count();
    assert_eq!(queue_full, 32);
}

#[test]
fn backpressure_counts_in_flight_work() {
    // queue_capacity >= max_batch: dispatched-but-unfinished batches keep
    // occupying the budget, so a burst beyond the capacity is shed even
    // though each window drains at max_batch
    let cfg = ServeConfig { queue_capacity: 8, ..serve_config(4, 8) };
    let mut server = Server::new(cfg).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 20));
    let report = server.run(burst(id, 512, 64, 2000)).unwrap();
    // burst at t=0: every admitted request stays outstanding (completions
    // are strictly after t=0), so exactly queue_capacity are admitted
    assert_eq!(report.completed, 8);
    assert_eq!(report.rejected, 56);
    assert_eq!(report.batch_sizes, vec![4, 4]);
}

#[test]
fn non_finite_timestamps_rejected_not_fatal() {
    let mut server = Server::new(serve_config(4, 8)).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 21));
    let mut trace = burst(id, 512, 2, 2100);
    trace[0].arrival_s = f64::NAN;
    trace.push(SpmvRequest {
        matrix: id,
        x: gen::dense_vector(512, 2200),
        alpha: 1.0,
        arrival_s: 0.0,
        deadline_s: Some(f64::INFINITY),
    });
    let report = server.run(trace).unwrap();
    assert!(matches!(
        report.outcomes[0],
        Outcome::Rejected(RejectReason::BadRequest)
    ));
    assert!(matches!(
        report.outcomes[2],
        Outcome::Rejected(RejectReason::BadRequest)
    ));
    // the finite request still completes
    assert!(matches!(report.outcomes[1], Outcome::Completed { .. }));
    assert_eq!(report.completed, 1);
    assert_eq!(report.rejected, 2);
}

#[test]
fn deadlines_expire_and_flag_late_requests() {
    // 1) deadline shorter than the flush wait: dropped before execution
    let cfg = ServeConfig { max_batch: 16, flush_deadline_s: 100e-6, ..serve_config(16, 8) };
    let mut server = Server::new(cfg).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 7));
    let mut trace = burst(id, 512, 4, 800);
    for r in &mut trace {
        r.deadline_s = Some(1e-6); // 1 µs budget vs 100 µs flush wait
    }
    let report = server.run(trace).unwrap();
    assert_eq!(report.expired, 4);
    assert_eq!(report.completed, 0);

    // 2) deadline longer than the wait but shorter than the service time:
    //    executed, counted as a deadline violation
    let cfg = ServeConfig { max_batch: 2, ..serve_config(2, 8) };
    let mut server = Server::new(cfg).unwrap();
    let id = server.register(csr_matrix(4_096, 200_000, 8));
    let mut trace = burst(id, 4_096, 2, 900);
    for r in &mut trace {
        r.deadline_s = Some(1e-9); // batch flushes instantly at t=0, so the
                                   // dispatch starts in time but finishes late
    }
    let report = server.run(trace).unwrap();
    assert_eq!(report.completed, 2);
    assert_eq!(report.deadline_violations, 2);
    assert!(report
        .outcomes
        .iter()
        .all(|o| matches!(o, Outcome::Completed { deadline_met: false, .. })));
}

#[test]
fn lru_eviction_under_tiny_cache() {
    // capacity-1 cache with alternating tenants: every dispatch misses and
    // evicts the other tenant's plan
    let mut server = Server::new(serve_config(2, 1)).unwrap();
    let ida = server.register(csr_matrix(512, 8_000, 9));
    let idb = server.register(csr_matrix(512, 8_000, 10));
    let mut trace = Vec::new();
    for round in 0..3usize {
        let t = round as f64 * 1e-3;
        for (j, id) in [ida, idb].into_iter().enumerate() {
            for i in 0..2 {
                trace.push(SpmvRequest {
                    matrix: id,
                    x: gen::dense_vector(512, (round * 10 + j * 5 + i) as u64),
                    alpha: 1.0,
                    // strictly ordered arrivals keep batches tenant-pure
                    arrival_s: t + (j * 2 + i) as f64 * 1e-9,
                    deadline_s: None,
                });
            }
        }
    }
    let report = server.run(trace).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.cache.hits, 0, "alternating tenants defeat a size-1 cache");
    assert_eq!(report.cache.misses, 6);
    assert!(report.cache.evictions >= 5);
}

#[test]
fn flush_deadline_bounds_straggler_latency() {
    // a lone request never fills the batch; the flush deadline dispatches it
    let cfg = ServeConfig { max_batch: 8, flush_deadline_s: 20e-6, ..serve_config(8, 8) };
    let mut server = Server::new(cfg).unwrap();
    let id = server.register(csr_matrix(512, 8_000, 11));
    let report = server
        .run(vec![SpmvRequest {
            matrix: id,
            x: gen::dense_vector(512, 12),
            alpha: 1.0,
            arrival_s: 0.0,
            deadline_s: None,
        }])
        .unwrap();
    assert_eq!(report.completed, 1);
    match &report.outcomes[0] {
        Outcome::Completed { latency_s, batch_k, .. } => {
            assert_eq!(*batch_k, 1);
            assert!(
                *latency_s >= 20e-6,
                "latency {latency_s} must include the flush wait"
            );
            assert!(*latency_s < 20e-6 + 1e-3, "latency {latency_s} looks unbounded");
        }
        other => panic!("expected Completed, got {other:?}"),
    }
}

#[test]
fn engine_pool_overlaps_independent_batches() {
    // two engines drain a two-tenant burst faster than one
    let mk = |engines: usize| {
        let cfg = ServeConfig { num_engines: engines, ..serve_config(8, 8) };
        let mut server = Server::new(cfg).unwrap();
        let ida = server.register(csr_matrix(2_048, 100_000, 13));
        let idb = server.register(csr_matrix(2_048, 100_000, 14));
        let mut trace = burst(ida, 2_048, 16, 1000);
        trace.extend(burst(idb, 2_048, 16, 1100));
        server.run(trace).unwrap()
    };
    let one = mk(1);
    let two = mk(2);
    assert_eq!(one.completed, 32);
    assert_eq!(two.completed, 32);
    assert!(
        two.makespan_s < one.makespan_s,
        "2 engines {} vs 1 engine {}",
        two.makespan_s,
        one.makespan_s
    );
}
