"""AOT pipeline: lower every bucketed L2 graph to HLO *text* + manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts [--quick]

``--quick`` emits only the smallest bucket of each kind — used by the python
test suite to validate the pipeline without paying for the full grid.
Incremental: an artifact is skipped if it already exists (the Makefile
handles staleness against the python sources).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import buckets, model

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the rust-loadable form)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def quick_subset(arts: list[dict]) -> list[dict]:
    """Smallest bucket of each kind — enough for pipeline tests."""
    out = []
    seen = set()
    for a in arts:
        if a["kind"] not in seen:
            seen.add(a["kind"])
            out.append(a)
    return out


def build(out_dir: str, quick: bool = False, force: bool = False, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    arts = buckets.all_artifacts()
    if quick:
        arts = quick_subset(arts)

    built, skipped = 0, 0
    t0 = time.time()
    for entry in arts:
        path = os.path.join(out_dir, entry["file"])
        if os.path.exists(path) and not force:
            skipped += 1
            continue
        t1 = time.time()
        lowered = model.lower_artifact(entry)
        text = to_hlo_text(lowered)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        built += 1
        if verbose:
            print(
                f"[aot] {entry['name']}: {len(text)} chars in {time.time() - t1:.2f}s",
                file=sys.stderr,
            )

    manifest = {
        "version": MANIFEST_VERSION,
        "quick": quick,
        "jax_version": jax.__version__,
        "dtype": buckets.DTYPE,
        "index_dtype": buckets.INDEX_DTYPE,
        "tile": buckets.TILE,
        "reduce_k": buckets.REDUCE_K,
        "nnz_buckets": buckets.NNZ_BUCKETS,
        "vec_buckets": buckets.VEC_BUCKETS,
        "artifacts": arts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(
            f"[aot] built {built}, skipped {skipped} (cached), "
            f"total {time.time() - t0:.1f}s -> {out_dir}",
            file=sys.stderr,
        )
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--quick", action="store_true", help="smallest bucket per kind only")
    p.add_argument("--force", action="store_true", help="rebuild even if present")
    args = p.parse_args()
    build(args.out_dir, quick=args.quick, force=args.force)


if __name__ == "__main__":
    main()
