"""Layer-2 JAX compute graphs, AOT-lowered to HLO artifacts for the rust runtime.

Three graph families (see DESIGN.md §4), each jitted per shape bucket:

  * ``spmv_partial_graph`` — wraps the L1 Pallas kernel; computes the partial
    result of one MSREP partition.  alpha/beta are *runtime scalar inputs*
    (rank-0 parameters), so one executable serves every (alpha, beta) — the
    scaling fuses into the same HLO module.
  * ``axpby_graph`` — ``y = a*p + b*y`` merge epilogue (used by the baseline
    path and the row-merge fix-up).
  * ``reduce_partials_graph`` — tree-sum of up to K partial vectors, the
    column-based (pCSC) merge that the paper runs on one GPU (§4.3).

Everything here is build-time only; the rust coordinator calls the compiled
artifacts through PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import buckets
from .kernels import spmm, spmv


def spmv_partial_graph(nnz_pad: int, n_pad: int, m_pad: int, tile: int | None = None):
    """Build the jittable partition-SpMV graph for one shape bucket.

    Signature (all parameters, in artifact input order):
      val:     f32[nnz_pad]
      col_idx: i32[nnz_pad]
      row_idx: i32[nnz_pad]
      x:       f32[n_pad]
      alpha:   f32[]          scale on the product (paper Alg. 1)
    Returns a 1-tuple (rust side unwraps with ``to_tuple1``):
      y_partial: f32[m_pad] = alpha * partition_spmv(...)
    """

    def fn(val, col_idx, row_idx, x, alpha):
        y = spmv.spmv_partial(
            val, col_idx, row_idx, x,
            nnz_pad=nnz_pad, n_pad=n_pad, m_pad=m_pad, tile=tile,
        )
        return (alpha * y,)

    return fn


def spmm_partial_graph(nnz_pad: int, n_pad: int, m_pad: int, k: int, tile: int | None = None):
    """Partition-SpMM graph (paper §2.3 multi-vector extension).

    Signature:
      val: f32[nnz_pad], col_idx/row_idx: i32[nnz_pad],
      x: f32[n_pad, k], alpha: f32[]
    Returns (y_partial: f32[m_pad, k],).
    """

    def fn(val, col_idx, row_idx, x, alpha):
        y = spmm.spmm_partial(
            val, col_idx, row_idx, x,
            nnz_pad=nnz_pad, n_pad=n_pad, m_pad=m_pad, k=k, tile=tile,
        )
        return (alpha * y,)

    return fn


def spmm_abstract_args(nnz_pad: int, n_pad: int, m_pad: int, k: int):
    f32, i32 = jnp.float32, jnp.int32
    return (
        jax.ShapeDtypeStruct((nnz_pad,), f32),
        jax.ShapeDtypeStruct((nnz_pad,), i32),
        jax.ShapeDtypeStruct((nnz_pad,), i32),
        jax.ShapeDtypeStruct((n_pad, k), f32),
        jax.ShapeDtypeStruct((), f32),
    )


def axpby_graph():
    """``y_out = a*p + b*y`` — merge epilogue. Shapes: p, y f32[m_pad]; a, b f32[]."""

    def fn(a, p, b, y):
        return (a * p + b * y,)

    return fn


def reduce_partials_graph():
    """Sum k partial vectors: parts f32[k, m_pad] -> f32[m_pad].

    The coordinator zero-pads unused slots, so one k=REDUCE_K executable
    serves any 1..=k fan-in.
    """

    def fn(parts):
        return (jnp.sum(parts, axis=0),)

    return fn


def spmv_abstract_args(nnz_pad: int, n_pad: int, m_pad: int):
    f32, i32 = jnp.float32, jnp.int32
    return (
        jax.ShapeDtypeStruct((nnz_pad,), f32),
        jax.ShapeDtypeStruct((nnz_pad,), i32),
        jax.ShapeDtypeStruct((nnz_pad,), i32),
        jax.ShapeDtypeStruct((n_pad,), f32),
        jax.ShapeDtypeStruct((), f32),
    )


def axpby_abstract_args(m_pad: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((m_pad,), f32),
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((m_pad,), f32),
    )


def reduce_abstract_args(m_pad: int, k: int = buckets.REDUCE_K):
    return (jax.ShapeDtypeStruct((k, m_pad), jnp.float32),)


def lower_artifact(entry: dict):
    """Lower one manifest entry to a ``jax.stages.Lowered`` object."""
    kind = entry["kind"]
    if kind == "spmv_partial":
        fn = spmv_partial_graph(
            entry["nnz_pad"], entry["n_pad"], entry["m_pad"], entry.get("tile")
        )
        args = spmv_abstract_args(entry["nnz_pad"], entry["n_pad"], entry["m_pad"])
    elif kind == "spmm_partial":
        fn = spmm_partial_graph(
            entry["nnz_pad"], entry["n_pad"], entry["m_pad"], entry["k"], entry.get("tile")
        )
        args = spmm_abstract_args(
            entry["nnz_pad"], entry["n_pad"], entry["m_pad"], entry["k"]
        )
    elif kind == "axpby":
        fn = axpby_graph()
        args = axpby_abstract_args(entry["m_pad"])
    elif kind == "reduce_partials":
        fn = reduce_partials_graph()
        args = reduce_abstract_args(entry["m_pad"], entry["k"])
    else:
        raise ValueError(f"unknown artifact kind: {kind}")
    return jax.jit(fn).lower(*args)
