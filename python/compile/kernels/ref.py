"""Pure-jnp correctness oracles for the MSREP kernels.

These are the ground truth the Pallas kernels (``spmv.py``) are validated
against in ``python/tests``.  They deliberately use the most direct jnp
formulation — no tiling, no pallas — so a bug cannot be shared between
implementation and oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def spmv_stream_ref(val, col_idx, row_idx, x, m):
    """COO-stream SpMV oracle: ``y[r] = sum_{k: row_idx[k]==r} val[k] * x[col_idx[k]]``.

    This is the semantics of one MSREP partition: a contiguous slice of the
    nnz stream with *local* row ids, producing a partial result of length
    ``m`` (the padded local row count).  Zero-padded ``val`` entries
    contribute nothing regardless of their index entries.
    """
    prod = val * x[col_idx]
    return jnp.zeros((m,), dtype=val.dtype).at[row_idx].add(prod)


def spmv_csr_ref(val, col_idx, row_ptr, x):
    """CSR SpMV oracle ``y = A @ x`` (loop form, mirrors paper Alg. 1 with
    alpha=1, beta=0). Only used for small test matrices."""
    m = row_ptr.shape[0] - 1
    rows = []
    for i in range(m):
        s, e = int(row_ptr[i]), int(row_ptr[i + 1])
        rows.append(jnp.sum(val[s:e] * x[col_idx[s:e]]))
    return jnp.stack(rows) if rows else jnp.zeros((0,), dtype=val.dtype)


def axpby_ref(a, x, b, y):
    """``a*x + b*y`` elementwise — the merge epilogue."""
    return a * x + b * y


def reduce_partials_ref(parts):
    """Sum a ``(k, m)`` stack of partial result vectors along axis 0 —
    the column-based (pCSC) merge tree reduction."""
    return jnp.sum(parts, axis=0)


def dense_spmv_ref(dense, x, alpha=1.0, beta=0.0, y=None):
    """Full GEMV semantics ``y = alpha*A@x + beta*y`` on a dense matrix."""
    base = alpha * (dense @ x)
    if y is None:
        return base
    return base + beta * y
