"""Layer-1 Pallas SpMM kernel: partition-SpMV against K dense vectors at once.

Paper §2.3 observes that "sparse matrix times multiple dense vectors have
similar behavior with SpMV" — the sparse stream is read once and amortized
over K right-hand sides, which is exactly the data-reuse MSREP's balanced
partitions preserve.  This kernel extends ``spmv.spmv_partial`` to a dense
block of K vectors:

  * the nnz stream is tiled into VMEM exactly like the SpMV kernel;
  * X (n_pad × K) and the Y accumulator (m_pad × K) stay resident;
  * per tile: gather K-wide rows of X, scale by val, scatter-add K-wide
    rows into Y — on real TPU hardware these are K-lane VPU ops, and for
    K ≥ 128 they would tile onto the MXU; at our K=8 the kernel remains
    VPU/memory bound like SpMV.

Same interpret=True constraints as ``spmv.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import buckets


def _spmm_kernel(val_ref, col_ref, row_ref, x_ref, y_ref):
    """One grid step over a TILE-sized slice of the nnz stream.

    Refs:
      val_ref : (TILE,)       f32
      col_ref : (TILE,)       i32
      row_ref : (TILE,)       i32   LOCAL row ids
      x_ref   : (N_PAD, K)    f32   resident across steps
      y_ref   : (M_PAD, K)    f32   resident accumulator
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    val = val_ref[...]
    col = col_ref[...]
    row = row_ref[...]
    x = x_ref[...]

    # (TILE, K): gather K-wide X rows and scale by the nnz values.
    prod = val[:, None] * x[col]

    # K-wide scatter-add by local row id.
    y_ref[...] = y_ref[...].at[row].add(prod)


@functools.partial(
    jax.jit, static_argnames=("nnz_pad", "n_pad", "m_pad", "k", "tile")
)
def spmm_partial(val, col_idx, row_idx, x, *, nnz_pad, n_pad, m_pad, k, tile=None):
    """Partial SpMM: ``Y[r, :] += sum val * X[col, :]`` per local row.

    Args:
      val:     f32[nnz_pad]
      col_idx: i32[nnz_pad]
      row_idx: i32[nnz_pad]  (local row ids)
      x:       f32[n_pad, k]
    Returns:
      f32[m_pad, k]
    """
    if tile is None:
        tile = min(buckets.TILE, nnz_pad)
    assert nnz_pad % tile == 0, (nnz_pad, tile)
    grid = (nnz_pad // tile,)

    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((n_pad, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m_pad, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, k), val.dtype),
        interpret=True,
    )(val, col_idx, row_idx, x)


def spmm_ref(val, col_idx, row_idx, x, m):
    """Pure-jnp oracle (mirrors ref.spmv_stream_ref, K-wide)."""
    prod = val[:, None] * x[col_idx]
    return jnp.zeros((m, x.shape[1]), dtype=val.dtype).at[row_idx].add(prod)


def vmem_footprint_bytes(nnz_pad: int, n_pad: int, m_pad: int, k: int, tile: int | None = None) -> dict:
    """VMEM working set of one grid step (K-wide residents)."""
    if tile is None:
        tile = min(buckets.TILE, nnz_pad)
    stream = 2 * tile * 4 * 3
    resident = (n_pad + m_pad) * 4 * k
    total = stream + resident
    return {
        "tile": tile,
        "stream_bytes": stream,
        "resident_bytes": resident,
        "total_bytes": total,
        "fits_16mib_vmem": total <= 16 * 1024 * 1024,
    }
