"""Layer-1 Pallas SpMV kernel: tiled gather + segment-reduce over the nnz stream.

Hardware-adaptation rationale (DESIGN.md §4): the paper's per-GPU kernel is
cuSparse CSR SpMV — warp-per-row scheduling, shared-memory staging, coalesced
HBM loads.  The transferable insight is *contiguous nnz-range processing with
balanced work per compute unit*, which is exactly what the pCSR/pCOO formats
expose.  On TPU the natural expression is:

  * the nnz stream (val / col_idx / row_idx) is tiled into fixed-size VMEM
    blocks via ``BlockSpec`` — one contiguous nnz-range per grid step, the
    same decomposition MSREP applies one level up (per GPU);
  * the dense ``x`` vector and the ``y`` accumulator stay resident in VMEM
    across grid steps (constant ``index_map``), mirroring cuSparse's reliance
    on caching x in L2/texture memory;
  * per tile: gather ``x[col]``, multiply, scatter-add by row id into the
    resident accumulator — the vector-unit-friendly form of the warp-level
    segmented reduction (no ballot/shuffle primitives on TPU).

SpMV contains no matmul, so the MXU is idle by design; the kernel is
VPU/memory bound.  DESIGN.md §8 reports the VMEM footprint and bytes/nnz
roofline per bucket instead of MXU utilization.

``interpret=True`` is mandatory in this environment: the CPU PJRT plugin
cannot execute Mosaic custom-calls.  Interpret-mode lowering produces plain
HLO (a while-loop over grid steps) that the rust runtime loads and runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import buckets


def _spmv_kernel(val_ref, col_ref, row_ref, x_ref, y_ref):
    """One grid step: process a TILE-sized contiguous slice of the nnz stream.

    Refs (all VMEM blocks):
      val_ref : (TILE,)  f32   non-zero values (zero-padded)
      col_ref : (TILE,)  i32   column index of each nnz (0-padded, in range)
      row_ref : (TILE,)  i32   LOCAL row index of each nnz (0-padded)
      x_ref   : (N_PAD,) f32   dense input vector, resident across steps
      y_ref   : (M_PAD,) f32   output accumulator, resident across steps
    """
    step = pl.program_id(0)

    # First visit of the resident y block: clear the accumulator.
    @pl.when(step == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    val = val_ref[...]
    col = col_ref[...]
    row = row_ref[...]
    x = x_ref[...]

    # Gather + multiply: the flops of SpMV.  Padding lanes have val == 0 so
    # their (valid-index) gathers contribute nothing.
    prod = val * x[col]

    # Segment reduction by local row id, accumulated into the resident block.
    # ``.at[].add`` is the TPU-friendly scatter-add; on real hardware Mosaic
    # lowers it onto the VPU, in interpret mode it is an XLA scatter.
    y_ref[...] = y_ref[...].at[row].add(prod)


@functools.partial(jax.jit, static_argnames=("nnz_pad", "n_pad", "m_pad", "tile"))
def spmv_partial(val, col_idx, row_idx, x, *, nnz_pad, n_pad, m_pad, tile=None):
    """Partial SpMV over a padded nnz stream: ``y[r] += sum val*x[col]`` per row.

    This is the single-device kernel MSREP schedules: it computes the partial
    result of ONE partition (pCSR / pCOO with local row ids, or pCSC with
    global row ids — the stream formulation covers all three, see
    DESIGN.md §2).  alpha/beta handling lives in the merge step (paper
    Alg. 3/5/7), not here.

    Args:
      val:     f32[nnz_pad]  values, zero-padded.
      col_idx: i32[nnz_pad]  column ids into x, padding entries in [0, n_pad).
      row_idx: i32[nnz_pad]  local row ids into y, padding entries in [0, m_pad).
      x:       f32[n_pad]    dense input vector (padded with zeros).
    Returns:
      f32[m_pad] partial result.
    """
    if tile is None:
        tile = min(buckets.TILE, nnz_pad)
    assert nnz_pad % tile == 0, (nnz_pad, tile)
    grid = (nnz_pad // tile,)

    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),      # val   — streamed
            pl.BlockSpec((tile,), lambda i: (i,)),      # col   — streamed
            pl.BlockSpec((tile,), lambda i: (i,)),      # row   — streamed
            pl.BlockSpec((n_pad,), lambda i: (0,)),     # x     — resident
        ],
        out_specs=pl.BlockSpec((m_pad,), lambda i: (0,)),  # y  — resident
        out_shape=jax.ShapeDtypeStruct((m_pad,), val.dtype),
        interpret=True,
    )(val, col_idx, row_idx, x)


def vmem_footprint_bytes(nnz_pad: int, n_pad: int, m_pad: int, tile: int | None = None) -> dict:
    """Estimate the VMEM working set of one grid step (DESIGN.md §8).

    Streams are double-buffered on real hardware, so they count twice; the
    resident x / y blocks count once.
    """
    if tile is None:
        tile = min(buckets.TILE, nnz_pad)
    stream = 2 * tile * 4 * 3          # val, col, row — double buffered
    resident = (n_pad + m_pad) * 4     # x + y
    total = stream + resident
    return {
        "tile": tile,
        "stream_bytes": stream,
        "resident_bytes": resident,
        "total_bytes": total,
        "fits_16mib_vmem": total <= 16 * 1024 * 1024,
    }


def bytes_per_nnz(nnz: int, m: int, n: int) -> float:
    """Memory-roofline bytes touched per non-zero for the stream kernel:
    12 B of stream (val+col+row) + amortized x/y traffic."""
    if nnz == 0:
        return 0.0
    return 12.0 + 4.0 * (m + n) / nnz
