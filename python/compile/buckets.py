"""Shape-bucket grid shared between the AOT pipeline and the rust runtime.

XLA artifacts need static shapes but MSREP partitions are dynamic: a pCSR /
pCOO / pCSC partition owns an arbitrary contiguous nnz-range and a row
(column) span that depends on the matrix.  We therefore AOT-compile a small
grid of shape *buckets* and let the rust runtime pad each partition up to the
nearest bucket (see DESIGN.md §4 "Static shapes / bucketing"):

  * ``NNZ_BUCKETS``  — padded length of the val/col_idx/row_idx streams.
  * ``VEC_BUCKETS``  — padded length of dense vectors (x input, y output).

Padding is harmless by construction: padded ``val`` entries are zero (so the
products contribute nothing), padded ``col_idx``/``row_idx`` entries are 0 (a
valid in-range index), and the rust side slices the first ``m`` entries of
the result.

``rust/src/runtime/buckets.rs`` mirrors these constants; the AOT pipeline
writes them into ``artifacts/manifest.json`` and the rust manifest loader
cross-checks at startup so the two sides can never silently diverge.
"""

from __future__ import annotations

# Padded nnz-stream lengths. ×2 spacing (§Perf iteration 3): the original
# ×4 grid measured 2.13x padding waste on the suite partitions, and padded
# nnz is what the interpret-mode kernel pays for — halving the spacing cut
# the measured engine hot path by ~25% for 2.4x as many (lazily compiled)
# artifacts.
NNZ_BUCKETS = [4_096, 8_192, 16_384, 32_768, 65_536, 131_072, 262_144, 524_288, 1_048_576]

# Padded dense-vector lengths (both the x input of length n and the
# y_partial output of length m_local / m use this grid).
VEC_BUCKETS = [4_096, 32_768, 262_144]

# Pallas grid tile: each grid step streams TILE non-zeros HBM->VMEM.
# §Perf sweep (EXPERIMENTS.md): 16Ki -> 14.2 ms, 64Ki -> 6.2 ms,
# 256Ki -> 1.6 ms per 256Ki-nnz partition on the XLA-CPU interpret path
# (fewer grid steps = less per-step loop overhead). 256Ki keeps the VMEM
# working set at 2·TILE·12 B (double-buffered streams) + residents
# ≈ 8.4 MiB, inside the 16 MiB budget for every bucket.
TILE = 262_144

# Fan-in of the on-GPU partial-result tree reduction used by the column-based
# (pCSC) merge path.  8 covers both evaluation platforms (6 and 8 GPUs).
REDUCE_K = 8

# SpMM (sparse matrix x K dense vectors, paper §2.3) right-hand-side width.
SPMM_K = 8

# SpMM keeps K-wide X and Y resident in VMEM, so its vector buckets stop at
# 32Ki: 262144 x 8 x 4 B x 2 would blow the 16 MiB budget.  Larger matrices
# fall back to K single-vector SpMV calls (the rust engine handles this).
SPMM_VEC_BUCKETS = [4_096, 32_768]

DTYPE = "float32"
INDEX_DTYPE = "int32"


def bucket_for(value: int, buckets: list[int]) -> int:
    """Smallest bucket >= value. Raises if value exceeds the largest bucket."""
    if value < 0:
        raise ValueError(f"negative size: {value}")
    for b in buckets:
        if value <= b:
            return b
    raise ValueError(f"size {value} exceeds largest bucket {buckets[-1]}")


def nnz_bucket(nnz: int) -> int:
    return bucket_for(nnz, NNZ_BUCKETS)


def vec_bucket(n: int) -> int:
    return bucket_for(n, VEC_BUCKETS)


def spmv_name(nnz_pad: int, n_pad: int, m_pad: int) -> str:
    return f"spmv_partial_nnz{nnz_pad}_n{n_pad}_m{m_pad}"


def spmm_name(nnz_pad: int, n_pad: int, m_pad: int) -> str:
    return f"spmm_partial_nnz{nnz_pad}_n{n_pad}_m{m_pad}_k{SPMM_K}"


def axpby_name(m_pad: int) -> str:
    return f"axpby_m{m_pad}"


def reduce_name(m_pad: int) -> str:
    return f"reduce_k{REDUCE_K}_m{m_pad}"


def all_artifacts() -> list[dict]:
    """Enumerate every artifact in the grid with its metadata record.

    The returned dicts become the entries of ``artifacts/manifest.json``.
    """
    arts: list[dict] = []
    for nnz_pad in NNZ_BUCKETS:
        for n_pad in VEC_BUCKETS:
            for m_pad in VEC_BUCKETS:
                name = spmv_name(nnz_pad, n_pad, m_pad)
                arts.append(
                    {
                        "name": name,
                        "kind": "spmv_partial",
                        "file": f"{name}.hlo.txt",
                        "nnz_pad": nnz_pad,
                        "n_pad": n_pad,
                        "m_pad": m_pad,
                        "tile": min(TILE, nnz_pad),
                    }
                )
    for nnz_pad in NNZ_BUCKETS:
        for n_pad in SPMM_VEC_BUCKETS:
            for m_pad in SPMM_VEC_BUCKETS:
                name = spmm_name(nnz_pad, n_pad, m_pad)
                arts.append(
                    {
                        "name": name,
                        "kind": "spmm_partial",
                        "file": f"{name}.hlo.txt",
                        "nnz_pad": nnz_pad,
                        "n_pad": n_pad,
                        "m_pad": m_pad,
                        "k": SPMM_K,
                        "tile": min(TILE, nnz_pad),
                    }
                )
    for m_pad in VEC_BUCKETS:
        name = axpby_name(m_pad)
        arts.append(
            {"name": name, "kind": "axpby", "file": f"{name}.hlo.txt", "m_pad": m_pad}
        )
    for m_pad in VEC_BUCKETS:
        name = reduce_name(m_pad)
        arts.append(
            {
                "name": name,
                "kind": "reduce_partials",
                "file": f"{name}.hlo.txt",
                "m_pad": m_pad,
                "k": REDUCE_K,
            }
        )
    return arts
