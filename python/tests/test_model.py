"""L2 graph tests: alpha scaling, merge epilogues, abstract-arg consistency."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import buckets, model
from compile.kernels import ref

F32 = np.float32
I32 = np.int32


def _stream(seed, nnz, nnz_pad, n, m):
    rng = np.random.default_rng(seed)
    val = np.zeros(nnz_pad, F32); val[:nnz] = rng.uniform(-1, 1, nnz)
    col = np.zeros(nnz_pad, I32); col[:nnz] = rng.integers(0, n, nnz)
    row = np.zeros(nnz_pad, I32); row[:nnz] = rng.integers(0, m, nnz)
    return val, col, row


class TestSpmvPartialGraph:
    def test_alpha_scales_output(self):
        nnz_pad = n_pad = m_pad = 64
        fn = model.spmv_partial_graph(nnz_pad, n_pad, m_pad, tile=32)
        val, col, row = _stream(0, 50, nnz_pad, 60, 60)
        x = np.random.default_rng(1).standard_normal(n_pad).astype(F32)
        (y1,) = fn(val, col, row, x, jnp.float32(1.0))
        (y3,) = fn(val, col, row, x, jnp.float32(3.0))
        np.testing.assert_allclose(np.asarray(y3), 3.0 * np.asarray(y1), rtol=1e-5, atol=1e-5)

    def test_alpha_zero_kills_output(self):
        nnz_pad = n_pad = m_pad = 64
        fn = model.spmv_partial_graph(nnz_pad, n_pad, m_pad, tile=64)
        val, col, row = _stream(2, 64, nnz_pad, 64, 64)
        x = np.ones(n_pad, F32)
        (y,) = fn(val, col, row, x, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(y), np.zeros(m_pad, F32))

    def test_matches_oracle_through_graph(self):
        nnz_pad, n_pad, m_pad = 256, 64, 64
        fn = model.spmv_partial_graph(nnz_pad, n_pad, m_pad, tile=64)
        val, col, row = _stream(5, 200, nnz_pad, 64, 64)
        x = np.random.default_rng(6).standard_normal(n_pad).astype(F32)
        (y,) = fn(val, col, row, x, jnp.float32(2.5))
        yr = 2.5 * np.asarray(
            ref.spmv_stream_ref(jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x), m_pad)
        )
        np.testing.assert_allclose(np.asarray(y), yr, rtol=1e-4, atol=1e-4)

    def test_abstract_args_shapes(self):
        args = model.spmv_abstract_args(128, 64, 32)
        assert [a.shape for a in args] == [(128,), (128,), (128,), (64,), ()]
        assert args[1].dtype == jnp.int32 and args[0].dtype == jnp.float32


class TestAxpbyGraph:
    @settings(max_examples=20, deadline=None)
    @given(
        a=st.floats(-10, 10), b=st.floats(-10, 10), seed=st.integers(0, 2**31 - 1)
    )
    def test_matches_ref(self, a, b, seed):
        rng = np.random.default_rng(seed)
        p = rng.standard_normal(32).astype(F32)
        y = rng.standard_normal(32).astype(F32)
        fn = model.axpby_graph()
        (out,) = fn(jnp.float32(a), p, jnp.float32(b), y)
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ref.axpby_ref(F32(a), jnp.array(p), F32(b), jnp.array(y))),
            rtol=1e-5, atol=1e-5,
        )

    def test_beta_zero_is_pure_scale(self):
        fn = model.axpby_graph()
        p = np.arange(8, dtype=F32)
        (out,) = fn(jnp.float32(2.0), p, jnp.float32(0.0), np.full(8, 999.0, F32))
        np.testing.assert_allclose(np.asarray(out), 2.0 * p)


class TestReduceGraph:
    def test_zero_padded_slots_ignored(self):
        fn = model.reduce_partials_graph()
        parts = np.zeros((buckets.REDUCE_K, 16), F32)
        parts[0] = 1.0
        parts[1] = 2.0
        (out,) = fn(parts)
        np.testing.assert_allclose(np.asarray(out), np.full(16, 3.0, F32))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), k_used=st.integers(1, buckets.REDUCE_K))
    def test_matches_ref(self, seed, k_used):
        rng = np.random.default_rng(seed)
        parts = np.zeros((buckets.REDUCE_K, 24), F32)
        parts[:k_used] = rng.standard_normal((k_used, 24))
        fn = model.reduce_partials_graph()
        (out,) = fn(parts)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.reduce_partials_ref(jnp.array(parts))),
            rtol=1e-5, atol=1e-5,
        )


class TestLowering:
    """Every artifact kind must lower; the HLO must have the declared layout."""

    @pytest.mark.parametrize("kind", ["spmv_partial", "axpby", "reduce_partials"])
    def test_lower_smallest_bucket(self, kind):
        entry = next(e for e in buckets.all_artifacts() if e["kind"] == kind)
        lowered = model.lower_artifact(entry)
        hlo = str(lowered.compiler_ir("stablehlo"))
        assert "func.func public @main" in hlo

    def test_spmv_hlo_io_shapes(self):
        entry = {
            "kind": "spmv_partial", "nnz_pad": 4096, "n_pad": 4096,
            "m_pad": 4096, "tile": 4096,
        }
        lowered = model.lower_artifact(entry)
        from compile.aot import to_hlo_text
        text = to_hlo_text(lowered)
        assert "f32[4096]" in text and "s32[4096]" in text
        # one executable output tuple
        assert "->(f32[4096]{0})" in text.replace(" ", "")
