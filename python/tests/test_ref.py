"""Oracle self-consistency: the jnp references agree with dense linear algebra."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

F32 = np.float32
I32 = np.int32


def random_dense(rng, m, n, density):
    dense = rng.standard_normal((m, n)).astype(F32)
    mask = rng.uniform(size=(m, n)) < density
    return dense * mask


def dense_to_stream(dense):
    rr, cc = np.nonzero(dense)
    return dense[rr, cc].astype(F32), cc.astype(I32), rr.astype(I32)


def dense_to_csr(dense):
    m = dense.shape[0]
    rr, cc = np.nonzero(dense)
    row_ptr = np.zeros(m + 1, I32)
    for r in rr:
        row_ptr[r + 1] += 1
    np.cumsum(row_ptr, out=row_ptr)
    return dense[rr, cc].astype(F32), cc.astype(I32), row_ptr


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 24),
    n=st.integers(1, 24),
    density=st.floats(0.0, 1.0),
)
def test_stream_ref_equals_dense(seed, m, n, density):
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, density)
    val, col, row = dense_to_stream(dense)
    x = rng.standard_normal(n).astype(F32)
    y = ref.spmv_stream_ref(jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x), m)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.integers(1, 16), n=st.integers(1, 16))
def test_csr_ref_equals_dense(seed, m, n):
    rng = np.random.default_rng(seed)
    dense = random_dense(rng, m, n, 0.3)
    val, col, row_ptr = dense_to_csr(dense)
    x = rng.standard_normal(n).astype(F32)
    y = ref.spmv_csr_ref(jnp.array(val), jnp.array(col), jnp.array(row_ptr), jnp.array(x))
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4, atol=1e-4)


def test_stream_and_csr_refs_agree():
    rng = np.random.default_rng(123)
    dense = random_dense(rng, 12, 15, 0.4)
    x = rng.standard_normal(15).astype(F32)
    val_s, col_s, row_s = dense_to_stream(dense)
    val_c, col_c, row_ptr = dense_to_csr(dense)
    y_s = ref.spmv_stream_ref(jnp.array(val_s), jnp.array(col_s), jnp.array(row_s), jnp.array(x), 12)
    y_c = ref.spmv_csr_ref(jnp.array(val_c), jnp.array(col_c), jnp.array(row_ptr), jnp.array(x))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_c), rtol=1e-5, atol=1e-5)


def test_dense_spmv_ref_alpha_beta():
    rng = np.random.default_rng(7)
    A = rng.standard_normal((5, 4)).astype(F32)
    x = rng.standard_normal(4).astype(F32)
    y = rng.standard_normal(5).astype(F32)
    out = ref.dense_spmv_ref(jnp.array(A), jnp.array(x), 2.0, 3.0, jnp.array(y))
    np.testing.assert_allclose(np.asarray(out), 2.0 * (A @ x) + 3.0 * y, rtol=1e-5)


def test_empty_matrix():
    y = ref.spmv_csr_ref(
        jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32),
        jnp.zeros((1,), jnp.int32), jnp.zeros((3,), jnp.float32),
    )
    assert y.shape == (0,)
