"""SpMM Pallas kernel vs oracle (the paper's §2.3 multi-vector extension)."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import buckets
from compile.kernels import ref, spmm

F32 = np.float32
I32 = np.int32


def make_inputs(rng, nnz, n, m, k, nnz_pad, n_pad):
    val = np.zeros(nnz_pad, F32)
    col = np.zeros(nnz_pad, I32)
    row = np.zeros(nnz_pad, I32)
    if nnz:
        val[:nnz] = rng.uniform(-1, 1, nnz)
        col[:nnz] = rng.integers(0, n, nnz)
        row[:nnz] = rng.integers(0, m, nnz)
    x = np.zeros((n_pad, k), F32)
    x[:n] = rng.standard_normal((n, k))
    return val, col, row, x


def run(val, col, row, x, nnz_pad, n_pad, m_pad, k, tile):
    return np.asarray(
        spmm.spmm_partial(
            jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x),
            nnz_pad=nnz_pad, n_pad=n_pad, m_pad=m_pad, k=k, tile=tile,
        )
    )


class TestFixed:
    def test_matches_oracle(self):
        rng = np.random.default_rng(0)
        nnz_pad = 256
        n_pad = m_pad = 64
        k = buckets.SPMM_K
        val, col, row, x = make_inputs(rng, 200, 60, 60, k, nnz_pad, n_pad)
        y = run(val, col, row, x, nnz_pad, n_pad, m_pad, k, tile=64)
        yr = np.asarray(spmm.spmm_ref(jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x), m_pad))
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)

    def test_each_column_equals_spmv(self):
        """SpMM column j == SpMV against X[:, j] (consistency across kernels)."""
        rng = np.random.default_rng(1)
        nnz_pad = 128
        n_pad = m_pad = 32
        k = buckets.SPMM_K
        val, col, row, x = make_inputs(rng, 100, 32, 32, k, nnz_pad, n_pad)
        y = run(val, col, row, x, nnz_pad, n_pad, m_pad, k, tile=32)
        for j in range(k):
            yv = np.asarray(
                ref.spmv_stream_ref(
                    jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x[:, j]), m_pad
                )
            )
            np.testing.assert_allclose(y[:, j], yv, rtol=1e-4, atol=1e-4, err_msg=f"col {j}")

    def test_all_padding_zero(self):
        k = buckets.SPMM_K
        y = run(
            np.zeros(64, F32), np.zeros(64, I32), np.zeros(64, I32),
            np.ones((32, k), F32), 64, 32, 32, k, tile=32,
        )
        np.testing.assert_array_equal(y, np.zeros((32, k), F32))

    def test_tiling_invariance(self):
        rng = np.random.default_rng(2)
        k = buckets.SPMM_K
        val, col, row, x = make_inputs(rng, 250, 64, 64, k, 256, 64)
        y1 = run(val, col, row, x, 256, 64, 64, k, tile=256)
        y2 = run(val, col, row, x, 256, 64, 64, k, tile=32)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


class TestHypothesis:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), frac=st.floats(0.0, 1.0))
    def test_random(self, seed, frac):
        rng = np.random.default_rng(seed)
        nnz_pad, n_pad, m_pad = 256, 64, 64
        k = buckets.SPMM_K
        nnz = int(frac * nnz_pad)
        val, col, row, x = make_inputs(rng, nnz, 64, 64, k, nnz_pad, n_pad)
        y = run(val, col, row, x, nnz_pad, n_pad, m_pad, k, tile=64)
        yr = np.asarray(spmm.spmm_ref(jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x), m_pad))
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)


class TestVmem:
    def test_spmm_buckets_fit_vmem(self):
        for e in buckets.all_artifacts():
            if e["kind"] != "spmm_partial":
                continue
            fp = spmm.vmem_footprint_bytes(
                e["nnz_pad"], e["n_pad"], e["m_pad"], e["k"], e["tile"]
            )
            assert fp["fits_16mib_vmem"], e

    def test_largest_vec_bucket_excluded_for_good_reason(self):
        """262144-wide SpMM residents would exceed VMEM — that is why
        SPMM_VEC_BUCKETS stops at 32Ki."""
        fp = spmm.vmem_footprint_bytes(65536, 262144, 262144, buckets.SPMM_K)
        assert not fp["fits_16mib_vmem"]

    def test_grid_counts(self):
        arts = [a for a in buckets.all_artifacts() if a["kind"] == "spmm_partial"]
        assert len(arts) == len(buckets.NNZ_BUCKETS) * len(buckets.SPMM_VEC_BUCKETS) ** 2
        assert all(a["k"] == buckets.SPMM_K for a in arts)
