"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps the shape/sparsity/tile space; the fixed cases pin the
regressions we care most about (padding semantics, duplicates, empty input).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import buckets
from compile.kernels import ref, spmv

F32 = np.float32
I32 = np.int32


def make_stream(rng, nnz, n, m, nnz_pad):
    """Random padded COO stream with values in [-1, 1]."""
    val = np.zeros(nnz_pad, F32)
    col = np.zeros(nnz_pad, I32)
    row = np.zeros(nnz_pad, I32)
    if nnz:
        val[:nnz] = rng.uniform(-1.0, 1.0, nnz).astype(F32)
        col[:nnz] = rng.integers(0, n, nnz)
        row[:nnz] = rng.integers(0, m, nnz)
    return val, col, row


def run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile):
    return np.asarray(
        spmv.spmv_partial(
            jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x),
            nnz_pad=nnz_pad, n_pad=n_pad, m_pad=m_pad, tile=tile,
        )
    )


def run_ref(val, col, row, x, m_pad):
    return np.asarray(
        ref.spmv_stream_ref(jnp.array(val), jnp.array(col), jnp.array(row), jnp.array(x), m_pad)
    )


class TestFixedCases:
    def test_identity_matrix(self):
        """A = I_8 => y == x (padded)."""
        nnz_pad = n_pad = m_pad = 64
        val = np.zeros(nnz_pad, F32)
        col = np.zeros(nnz_pad, I32)
        row = np.zeros(nnz_pad, I32)
        val[:8] = 1.0
        col[:8] = np.arange(8)
        row[:8] = np.arange(8)
        x = np.zeros(n_pad, F32)
        x[:8] = np.arange(1, 9)
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=16)
        np.testing.assert_allclose(y[:8], x[:8])
        np.testing.assert_allclose(y[8:], 0.0)

    def test_paper_example_matrix(self):
        """The 6x6 example matrix of paper Fig. 1, y = A @ ones."""
        dense = np.array(
            [
                [10, 0, 0, 0, -2, 0],
                [3, 9, 0, 0, 0, 3],
                [0, 7, 8, 7, 0, 0],
                [3, 0, 8, 7, 5, 0],
                [0, 8, 0, 9, 9, 13],
                [0, 4, 0, 0, 2, -1],
            ],
            dtype=F32,
        )
        rr, cc = np.nonzero(dense)
        nnz = len(rr)
        nnz_pad = n_pad = m_pad = 32
        val = np.zeros(nnz_pad, F32)
        col = np.zeros(nnz_pad, I32)
        row = np.zeros(nnz_pad, I32)
        val[:nnz] = dense[rr, cc]
        col[:nnz] = cc
        row[:nnz] = rr
        x = np.zeros(n_pad, F32)
        x[:6] = 1.0
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=8)
        np.testing.assert_allclose(y[:6], dense.sum(axis=1))

    def test_all_padding_is_zero(self):
        """A fully padded (nnz=0) stream must produce exactly zero."""
        nnz_pad, n_pad, m_pad = 128, 64, 64
        val = np.zeros(nnz_pad, F32)
        col = np.zeros(nnz_pad, I32)
        row = np.zeros(nnz_pad, I32)
        x = np.full(n_pad, 7.0, F32)  # nonzero x exercises val==0 masking
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=32)
        np.testing.assert_array_equal(y, np.zeros(m_pad, F32))

    def test_duplicate_coordinates_accumulate(self):
        """Multiple stream entries on the same (row, col) must sum."""
        nnz_pad = n_pad = m_pad = 16
        val = np.zeros(nnz_pad, F32)
        col = np.zeros(nnz_pad, I32)
        row = np.zeros(nnz_pad, I32)
        val[:4] = [1.0, 2.0, 3.0, 4.0]
        col[:4] = [5, 5, 5, 5]
        row[:4] = [3, 3, 3, 3]
        x = np.zeros(n_pad, F32)
        x[5] = 2.0
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=16)
        assert y[3] == pytest.approx(20.0)
        assert np.count_nonzero(y) == 1

    def test_single_tile_equals_multi_tile(self):
        """Tiling must not change the result (accumulator correctness)."""
        rng = np.random.default_rng(42)
        nnz_pad, n_pad, m_pad = 256, 64, 64
        val, col, row = make_stream(rng, 200, 60, 60, nnz_pad)
        x = rng.standard_normal(n_pad).astype(F32)
        y1 = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=256)
        y2 = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=32)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)

    def test_row_concentration(self):
        """Power-law extreme: every nnz lands in one row (worst-case skew)."""
        rng = np.random.default_rng(3)
        nnz_pad, n_pad, m_pad = 512, 128, 128
        val = np.zeros(nnz_pad, F32)
        col = np.zeros(nnz_pad, I32)
        row = np.zeros(nnz_pad, I32)
        val[:500] = rng.uniform(-1, 1, 500).astype(F32)
        col[:500] = rng.integers(0, 128, 500)
        row[:500] = 17
        x = rng.standard_normal(n_pad).astype(F32)
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile=64)
        yr = run_ref(val, col, row, x, m_pad)
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)

    def test_matches_csr_oracle(self):
        """Stream kernel on a CSR-expanded matrix == row-loop CSR oracle."""
        rng = np.random.default_rng(9)
        m, n, nnz = 40, 50, 300
        nnz_pad, n_pad, m_pad = 512, 64, 64
        # random CSR
        counts = rng.multinomial(nnz, np.ones(m) / m)
        row_ptr = np.zeros(m + 1, I32)
        np.cumsum(counts, out=row_ptr[1:])
        col_idx = rng.integers(0, n, nnz).astype(I32)
        vals = rng.uniform(-1, 1, nnz).astype(F32)
        x = rng.standard_normal(n).astype(F32)
        y_csr = np.asarray(
            ref.spmv_csr_ref(jnp.array(vals), jnp.array(col_idx), jnp.array(row_ptr), jnp.array(x))
        )
        # expand to stream
        row_ids = np.repeat(np.arange(m, dtype=I32), counts)
        val_p = np.zeros(nnz_pad, F32); val_p[:nnz] = vals
        col_p = np.zeros(nnz_pad, I32); col_p[:nnz] = col_idx
        row_p = np.zeros(nnz_pad, I32); row_p[:nnz] = row_ids
        x_p = np.zeros(n_pad, F32); x_p[:n] = x
        y = run_kernel(val_p, col_p, row_p, x_p, nnz_pad, n_pad, m_pad, tile=128)
        np.testing.assert_allclose(y[:m], y_csr, rtol=1e-4, atol=1e-5)


class TestHypothesisSweep:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        nnz_frac=st.floats(0.0, 1.0),
        shape=st.sampled_from([(64, 64, 64), (256, 64, 64), (256, 128, 32), (1024, 256, 256)]),
        tile_div=st.sampled_from([1, 2, 4, 8]),
    )
    def test_random_streams(self, seed, nnz_frac, shape, tile_div):
        nnz_pad, n_pad, m_pad = shape
        tile = nnz_pad // tile_div
        rng = np.random.default_rng(seed)
        nnz = int(nnz_frac * nnz_pad)
        n = rng.integers(1, n_pad + 1)
        m = rng.integers(1, m_pad + 1)
        val, col, row = make_stream(rng, nnz, n, m, nnz_pad)
        x = np.zeros(n_pad, F32)
        x[:n] = rng.standard_normal(n)
        y = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, tile)
        yr = run_ref(val, col, row, x, m_pad)
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_linearity_in_x(self, seed):
        """SpMV is linear: K(2x) == 2*K(x)."""
        rng = np.random.default_rng(seed)
        nnz_pad, n_pad, m_pad = 256, 64, 64
        val, col, row = make_stream(rng, 200, 64, 64, nnz_pad)
        x = rng.standard_normal(n_pad).astype(F32)
        y1 = run_kernel(val, col, row, x, nnz_pad, n_pad, m_pad, 64)
        y2 = run_kernel(val, col, row, (2.0 * x).astype(F32), nnz_pad, n_pad, m_pad, 64)
        np.testing.assert_allclose(y2, 2.0 * y1, rtol=1e-4, atol=1e-4)


class TestVmemModel:
    def test_all_buckets_fit_vmem(self):
        """Every bucket in the AOT grid must fit the 16 MiB VMEM budget."""
        for e in buckets.all_artifacts():
            if e["kind"] != "spmv_partial":
                continue
            fp = spmv.vmem_footprint_bytes(e["nnz_pad"], e["n_pad"], e["m_pad"], e["tile"])
            assert fp["fits_16mib_vmem"], e

    def test_footprint_monotone_in_tile(self):
        a = spmv.vmem_footprint_bytes(65536, 4096, 4096, tile=1024)
        b = spmv.vmem_footprint_bytes(65536, 4096, 4096, tile=16384)
        assert a["total_bytes"] < b["total_bytes"]

    def test_bytes_per_nnz_roofline(self):
        # Dense-ish stream: amortized x/y traffic vanishes, -> 12 B/nnz.
        assert spmv.bytes_per_nnz(10**9, 10**3, 10**3) == pytest.approx(12.0, abs=0.1)
        assert spmv.bytes_per_nnz(0, 10, 10) == 0.0
