"""AOT pipeline tests: HLO-text emission, manifest schema, incrementality."""

from __future__ import annotations

import json
import os

from compile import aot, buckets, model


class TestHloText:
    def test_hlo_text_structure(self):
        entry = next(e for e in buckets.all_artifacts() if e["kind"] == "axpby")
        text = aot.to_hlo_text(model.lower_artifact(entry))
        # HLO text (not proto) is the interchange format: the rust loader
        # parses this with HloModuleProto::from_text_file.
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        assert f"f32[{entry['m_pad']}]" in text

    def test_return_tuple_layout(self):
        """Outputs are 1-tuples so rust unwraps with to_tuple1()."""
        entry = next(e for e in buckets.all_artifacts() if e["kind"] == "reduce_partials")
        text = aot.to_hlo_text(model.lower_artifact(entry))
        compact = text.replace(" ", "")
        assert f"->(f32[{entry['m_pad']}]{{0}})" in compact


class TestBuild:
    def test_quick_build_and_manifest(self, tmp_path):
        out = str(tmp_path / "arts")
        manifest = aot.build(out, quick=True, verbose=False)
        # one artifact per kind
        kinds = {a["kind"] for a in aot.quick_subset(buckets.all_artifacts())}
        assert kinds == {"spmv_partial", "spmm_partial", "axpby", "reduce_partials"}
        files = os.listdir(out)
        assert "manifest.json" in files
        for a in aot.quick_subset(buckets.all_artifacts()):
            assert a["file"] in files
        with open(os.path.join(out, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk["version"] == aot.MANIFEST_VERSION
        assert on_disk["nnz_buckets"] == buckets.NNZ_BUCKETS
        assert on_disk["vec_buckets"] == buckets.VEC_BUCKETS
        assert on_disk == json.loads(json.dumps(manifest))

    def test_incremental_skips_existing(self, tmp_path):
        out = str(tmp_path / "arts")
        aot.build(out, quick=True, verbose=False)
        entry = aot.quick_subset(buckets.all_artifacts())[0]
        path = os.path.join(out, entry["file"])
        mtime = os.path.getmtime(path)
        aot.build(out, quick=True, verbose=False)
        assert os.path.getmtime(path) == mtime  # untouched

    def test_force_rebuilds(self, tmp_path):
        out = str(tmp_path / "arts")
        aot.build(out, quick=True, verbose=False)
        entry = aot.quick_subset(buckets.all_artifacts())[0]
        path = os.path.join(out, entry["file"])
        with open(path, "w") as f:
            f.write("garbage")
        aot.build(out, quick=True, force=True, verbose=False)
        with open(path) as f:
            assert f.read().startswith("HloModule")

    def test_manifest_artifact_records_complete(self, tmp_path):
        for a in buckets.all_artifacts():
            assert a["kind"] in ("spmv_partial", "spmm_partial", "axpby", "reduce_partials")
            if a["kind"] == "spmv_partial":
                assert {"nnz_pad", "n_pad", "m_pad", "tile"} <= a.keys()
            elif a["kind"] == "spmm_partial":
                assert {"nnz_pad", "n_pad", "m_pad", "k", "tile"} <= a.keys()
            else:
                assert "m_pad" in a


class TestRepoArtifacts:
    """The checked-out artifacts/ dir (built by `make artifacts`) is coherent."""

    ARTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    def test_manifest_matches_grid(self):
        mpath = os.path.join(self.ARTS, "manifest.json")
        if not os.path.exists(mpath):
            import pytest

            pytest.skip("artifacts not built yet")
        with open(mpath) as f:
            m = json.load(f)
        assert m["nnz_buckets"] == buckets.NNZ_BUCKETS
        assert m["vec_buckets"] == buckets.VEC_BUCKETS
        for a in m["artifacts"]:
            assert os.path.exists(os.path.join(self.ARTS, a["file"])), a["name"]
