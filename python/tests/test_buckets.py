"""Bucket-grid invariants — the contract shared with rust/src/runtime/buckets.rs."""

from __future__ import annotations

import pytest
from hypothesis import given, strategies as st

from compile import buckets


class TestBucketFor:
    def test_exact_match_returns_bucket(self):
        for b in buckets.NNZ_BUCKETS:
            assert buckets.nnz_bucket(b) == b

    def test_zero_maps_to_smallest(self):
        assert buckets.nnz_bucket(0) == buckets.NNZ_BUCKETS[0]
        assert buckets.vec_bucket(0) == buckets.VEC_BUCKETS[0]

    def test_one_past_bucket_rounds_up(self):
        assert buckets.nnz_bucket(buckets.NNZ_BUCKETS[0] + 1) == buckets.NNZ_BUCKETS[1]

    def test_overflow_raises(self):
        with pytest.raises(ValueError):
            buckets.nnz_bucket(buckets.NNZ_BUCKETS[-1] + 1)

    def test_negative_raises(self):
        with pytest.raises(ValueError):
            buckets.nnz_bucket(-1)

    @given(v=st.integers(0, buckets.NNZ_BUCKETS[-1]))
    def test_bucket_is_smallest_upper_bound(self, v):
        b = buckets.nnz_bucket(v)
        assert b >= v
        smaller = [x for x in buckets.NNZ_BUCKETS if x < b]
        assert all(x < v for x in smaller)

    @given(v=st.integers(1, buckets.NNZ_BUCKETS[-1]))
    def test_padding_waste_bounded(self, v):
        """x4 spacing => padded size < 4x the request (the §Perf waste bound)."""
        assert buckets.nnz_bucket(v) < 4 * v + buckets.NNZ_BUCKETS[0]


class TestGridEnumeration:
    def test_counts(self):
        arts = buckets.all_artifacts()
        n_spmv = len(buckets.NNZ_BUCKETS) * len(buckets.VEC_BUCKETS) ** 2
        assert len([a for a in arts if a["kind"] == "spmv_partial"]) == n_spmv
        assert len([a for a in arts if a["kind"] == "axpby"]) == len(buckets.VEC_BUCKETS)
        assert len([a for a in arts if a["kind"] == "reduce_partials"]) == len(buckets.VEC_BUCKETS)

    def test_names_unique(self):
        arts = buckets.all_artifacts()
        names = [a["name"] for a in arts]
        assert len(names) == len(set(names))
        files = [a["file"] for a in arts]
        assert len(files) == len(set(files))

    def test_tile_divides_nnz_pad(self):
        for a in buckets.all_artifacts():
            if a["kind"] == "spmv_partial":
                assert a["nnz_pad"] % a["tile"] == 0

    def test_buckets_sorted_ascending(self):
        assert buckets.NNZ_BUCKETS == sorted(buckets.NNZ_BUCKETS)
        assert buckets.VEC_BUCKETS == sorted(buckets.VEC_BUCKETS)
        assert len(set(buckets.NNZ_BUCKETS)) == len(buckets.NNZ_BUCKETS)

    def test_name_roundtrip(self):
        assert buckets.spmv_name(1, 2, 3) == "spmv_partial_nnz1_n2_m3"
        assert buckets.axpby_name(7) == "axpby_m7"
        assert buckets.reduce_name(9) == f"reduce_k{buckets.REDUCE_K}_m9"
